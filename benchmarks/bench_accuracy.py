"""Table 1 proxy: train the paper-scale model with M=4 simulated workers
for each method (3 bits) and report final train loss + next-token
accuracy.  The paper's claim to reproduce: adaptive methods (ALQ/AMQ)
close most of the gap to full-precision SuperSGD and beat the
fixed-grid baselines (QSGDinf / NUQSGD / TRN)."""
from __future__ import annotations

import numpy as np

from repro.core.schemes import QuantScheme
from .common import SimWorkers, emit

METHODS = ["fp32", "alq", "alq_n", "amq", "amq_n", "qsgdinf", "nuqsgd",
           "trn"]


def run(steps: int = 100, M: int = 4):
    results = {}
    # 2 bits: the regime where grid quality separates methods most
    # (paper Fig. 7b); 3-bit differences need full CIFAR-length runs.
    for m in METHODS:
        bits = 2
        sw = SimWorkers(QuantScheme(name=m, bits=bits, bucket_size=1024),
                        M=M, seed=0, lr=3e-3)
        metr = sw.run(steps, update_at=(2, 10, 30))
        acc = sw.eval_accuracy()
        loss = float(np.mean(metr["loss"][-5:]))
        results[m] = (loss, acc)
        emit(f"table1/{m}", 0.0,
             f"final_loss={loss:.4f};val_acc={acc:.4f};M={M};bits={bits}")
    # headline check (printed, asserted softly): ALQ beats fixed grids
    if results["alq"][0] < results["nuqsgd"][0]:
        emit("table1/claim_alq_beats_nuqsgd", 0.0, "confirmed=1")
    else:
        emit("table1/claim_alq_beats_nuqsgd", 0.0, "confirmed=0")


if __name__ == "__main__":
    run()

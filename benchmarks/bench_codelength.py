"""Thm 3 / App. D: expected wire bits per coordinate — entropy H(L),
Huffman expected length, the fixed-width lattice the collectives use,
and the Theorem-3 upper bound — per method and bit width."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (code_length_bound, entropy_bits,
                        expected_bits_per_coordinate, level_probabilities,
                        packing)
from repro.core.schemes import QuantScheme
from repro.core.stats import TruncNormStats
from repro.dist.sync import gather_stats
from .common import emit


def run(d: int = 131072):
    g = jax.random.normal(jax.random.PRNGKey(0), (d,)) * 0.01
    for name in ("alq", "amq", "qsgdinf", "nuqsgd", "trn"):
        for bits in ((2, 3, 4) if name != "trn" else (2,)):
            scheme = QuantScheme(name=name, bits=bits, bucket_size=4096)
            state = scheme.init_state()
            stats = jax.jit(lambda f: gather_stats(f, scheme, axes=()))(g)
            if scheme.adaptive:
                state = scheme.update_state(state, stats)
            probs = level_probabilities(state.levels, stats)
            H = float(entropy_bits(probs))
            eb = expected_bits_per_coordinate(state.levels, stats)
            wire = packing.wire_bits_for(scheme.num_levels)
            bound = code_length_bound(state.levels, stats, d) / d
            emit(f"thm3/{name}/bits={bits}", 0.0,
                 f"H={H:.3f};huffman+sign={eb:.3f};fixed_wire={wire};"
                 f"thm3_bound_per_coord={bound:.3f}")


if __name__ == "__main__":
    run()

"""Compression algorithms at EQUAL wire budget: error vs bits/coord.

Three algorithms over the same 2-bit-budget wire (``repro.compress``):

  * ``plain``  — the stateless dense grid (today's path);
  * ``ef``     — error feedback on the same dense grid (zero extra
                 wire bytes: the residual never travels);
  * ``topk``   — EF + SparseCodec at the equal-budget default k, so
                 index+value payloads cost what the dense symbols would.

The gradient model is the heterogeneous-bucket stream of
``bench_mixed_bits`` (per-bucket scales spanning three decades, the
layer-norm / embedding / attention spread real flattened gradients
show) plus a persistent mean component — the setting where per-step
quantization noise both matters and accumulates.  Measured end to end
through ``compressed_allreduce`` (all_gather mode, M=4 logical workers
under vmap, production key schedule), over T steps and several seeds:

  * END-OF-RUN CUMULATIVE aggregate error ||sum_t (agg_t - exact_t)||^2
    — the quantity error feedback bounds (a stateless wire random-walks
    at ~T * per-step variance);
  * mean per-step aggregate error (where top-k pays for its dropped
    support and EF pays nothing);
  * exact shipped bits/coord from the codec plans (equal by
    construction, asserted).

Writes ``BENCH_compress.json`` (committed artifact).  The acceptance
claim of the algorithm layer: at equal bits/coord, ``ef`` and ``topk``
achieve strictly lower end-of-run cumulative error than ``plain``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.compress import make_algorithm
from repro.core.schemes import QuantScheme
from repro.dist import sync

M = 4
BS = 512
NB = 32            # buckets per worker
BITS = 2           # dense width == the sparse codec's wire budget
T = 20             # steps per run
SEEDS = range(4)

D = NB * BS


def grad_stream(seed: int, t: int) -> jnp.ndarray:
    """(M, d): persistent heterogeneous mean + fresh per-step noise."""
    scales = jnp.asarray(
        np.geomspace(1e-3, 1.0, NB), jnp.float32)[None, :, None]
    mean = (jax.random.normal(jax.random.PRNGKey(100 + seed),
                              (M, NB, BS)) * scales)
    noise = (jax.random.normal(jax.random.PRNGKey(7000 + 97 * seed + t),
                               (M, NB, BS)) * scales * 0.2)
    return (mean + noise).reshape(M, D) * 0.01


def run(spec: str, scheme: QuantScheme, seed: int):
    state = scheme.init_state()
    algo = make_algorithm(spec, scheme)
    comp = jax.vmap(lambda _: algo.init_state(D))(jnp.arange(M))
    step = jax.jit(jax.vmap(
        lambda g, c, k: sync.compressed_allreduce(
            g, scheme, state, algo, c, k, axes=("w",),
            use_pallas=False),
        axis_name="w", in_axes=(0, 0, None)))
    cum = np.zeros(D)
    step_errs, bits = [], None
    for t in range(T):
        g = grad_stream(seed, t)
        key = jax.random.fold_in(jax.random.PRNGKey(11 + seed), t)
        out, comp, m = step(g, comp, key)
        exact = np.asarray(g, np.float64).mean(0)
        diff = np.asarray(out[0], np.float64) - exact
        step_errs.append(float((diff ** 2).sum()))
        cum += diff
        bits = float(m.comm_bits_per_coord[0])
    return {
        "cum_err": float((cum ** 2).sum()),
        "mean_step_err": float(np.mean(step_errs)),
        "bits_per_coord": bits,
        "kept_fraction": float(algo.kept_fraction),
    }


def main():
    scheme = QuantScheme(name="qsgdinf", bits=BITS, bucket_size=BS)
    results = {}
    for spec in ("plain", "ef", "topk"):
        runs = [run(spec, scheme, s) for s in SEEDS]
        results[spec] = {
            "cum_err": float(np.mean([r["cum_err"] for r in runs])),
            "mean_step_err": float(
                np.mean([r["mean_step_err"] for r in runs])),
            "bits_per_coord": runs[0]["bits_per_coord"],
            "kept_fraction": runs[0]["kept_fraction"],
        }
        common.emit(f"compress_{spec}", 0.0,
                    f"cum_err={results[spec]['cum_err']:.4g} "
                    f"bits={results[spec]['bits_per_coord']:.3f}")

    # equal wire budget by construction: topk's plan never exceeds the
    # dense plan's bits/coord
    assert results["ef"]["bits_per_coord"] \
        == results["plain"]["bits_per_coord"]
    assert results["topk"]["bits_per_coord"] \
        <= results["plain"]["bits_per_coord"] + 1e-6
    # the acceptance claim: state strictly beats stateless at equal bits
    assert results["ef"]["cum_err"] < results["plain"]["cum_err"]
    assert results["topk"]["cum_err"] < results["plain"]["cum_err"]

    gain_ef = results["plain"]["cum_err"] / results["ef"]["cum_err"]
    gain_tk = results["plain"]["cum_err"] / results["topk"]["cum_err"]
    print(f"cumulative-error gain at equal {BITS}-bit budget: "
          f"ef {gain_ef:.1f}x, topk {gain_tk:.1f}x")

    common.write_results(
        "compress",
        config={"workers": M, "bucket_size": BS, "buckets": NB,
                "bits": BITS, "steps": T, "seeds": len(list(SEEDS)),
                "scheme": "qsgdinf"},
        metrics={"algorithms": results,
                 "cum_err_gain_ef": gain_ef,
                 "cum_err_gain_topk": gain_tk})


if __name__ == "__main__":
    main()

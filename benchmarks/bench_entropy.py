"""Measured entropy-coded wire bits vs the meter vs the fixed plan.

Since PR 3 the achievable entropy-coded cost of the adaptive grid is
*metered* (``SchemeState.entropy_bits``); the ``EntropyCodec`` realizes
it as actual coded bytes.  This benchmark runs the simulator's
``entropy_coded`` protocol — real model, real ALQ level adaptation,
canonical-Huffman table re-fit at every level-update milestone — at
2/3/4-bit schemes and records, per training step:

  * ``measured``  worker-0 shipped wire bits/coord, read off the
                  per-bucket coded-length headers (what the cost model
                  bills);
  * ``metered``   ``entropy_bits_per_coord`` — H(L) + sign bits of the
                  current grid under the last fitted stats;
  * ``fixed``     the uniform codec's exact shipped bits/coord.

Writes ``BENCH_entropy.json`` (committed artifact).  Acceptance: on the
adaptive trajectory (after the first level update + table refit) the
measured wire is strictly below the fixed-width plan, and the measured
symbol cost (measured minus the static header+norm side-channel) sits
within ~15% of the metered entropy curve.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import common
from repro.sim.scenario import Scenario, _run_cell

BITS = (2, 3, 4)
STEPS = 10
MILESTONES = (2, 6)
BUCKET = 512


def main():
    scn = Scenario(
        name="bench_entropy",
        description="entropy-coded wire trajectory at 2/3/4 bits",
        schemes=tuple(f"alq:{b}" for b in BITS),
        topologies=("allreduce",),
        codec="entropy",
        bucket_size=BUCKET,
        steps=STEPS,
        update_milestones=MILESTONES,
    )
    # header word + fp32 norm word per bucket: the static per-bucket
    # side-channel the symbol-cost comparison factors out
    overhead = 2 * 32.0 / BUCKET

    cells = {}
    for b in BITS:
        cell = _run_cell(scn, f"alq:{b}", "allreduce", "plain", STEPS,
                         use_pallas=False)
        fixed = cell["fixed_bits_per_coord"]
        steps = cell["steps"]
        adapted = [s for s in steps if s["step"] > MILESTONES[0]]
        measured = float(np.mean(
            [s["measured_bits_per_coord"] for s in adapted]))
        metered = float(np.mean(
            [s["entropy_bits_per_coord"] for s in adapted]))
        rel = (measured - overhead - metered) / metered
        cells[str(b)] = {
            "fixed_bits_per_coord": fixed,
            "measured_bits_per_coord": measured,
            "metered_entropy_bits_per_coord": metered,
            "measured_symbol_bits": measured - overhead,
            "rel_gap_vs_metered": rel,
            "savings_vs_fixed": 1.0 - measured / fixed,
            "table_refits": cell["table_refits"],
            "trajectory": [
                {k: s[k] for k in ("step", "measured_bits_per_coord",
                                   "entropy_bits_per_coord")}
                for s in steps],
        }
        common.emit(
            f"entropy/alq:{b}", 0.0,
            f"measured={measured:.3f} metered={metered:.3f} "
            f"fixed={fixed:.3f} rel={rel:+.1%}")
        assert measured < fixed, (b, measured, fixed)
        assert abs(rel) <= 0.15, (b, rel)

    common.write_results(
        "entropy",
        config={**dataclasses.asdict(scn),
                "overhead_bits_per_coord": overhead,
                "note": "measured/metered averaged over the adaptive "
                        "trajectory (steps after the first level "
                        "update + table refit)"},
        metrics=cells)

    print("\nbits  fixed   measured  metered  rel")
    for b in BITS:
        c = cells[str(b)]
        print(f"{b}     {c['fixed_bits_per_coord']:.3f}   "
              f"{c['measured_bits_per_coord']:.3f}     "
              f"{c['metered_entropy_bits_per_coord']:.3f}    "
              f"{c['rel_gap_vs_metered']:+.1%}")


if __name__ == "__main__":
    main()

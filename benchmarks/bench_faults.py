"""Wire corruption vs aggregate damage: integrity words on and off.

Sweeps the per-word bit-flip probability on the gathered allreduce
payloads (``dist.faults.FaultyTransport``, production key schedule,
M=4 logical workers under vmap) and measures the per-step aggregate
error against the exact fp32 mean, with the SAME codec run two ways:

  * ``integrity=False`` — today's bare wire: a flipped bit decodes
    silently into a wrong (possibly NaN — corrupt norm words) gradient
    that the mean then smears over every coordinate;
  * ``integrity=True``  — per-bucket checksum words: detected-corrupt
    buckets are excluded and the survivors renormalized per bucket.

The acceptance claim charted here: the integrity-on aggregate error
stays FINITE and within a bounded factor of the fault-free
quantization error at every rate (the only loss is the excluded
buckets' contribution to the mean), while the bare wire's error is
unbounded — one corrupt norm word turns the whole aggregate NaN, which
happens with near-certainty once flips are common enough (p >= 1e-3
here).  At vanishing rates a lucky flip in a low-order symbol bit can
cost the bare wire *less* than exclusion costs integrity — the
protection buys a bounded tail, not a lower mean at epsilon rates —
and it costs exactly one word per bucket (``32/bucket_size``
bits/coord).

Writes ``BENCH_faults.json`` (committed artifact).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.codec import codec_for_scheme
from repro.core.schemes import QuantScheme
from repro.dist.faults import FaultModel, FaultyTransport
from repro.dist.sync import quantized_allreduce
from repro.dist.transport import MeshTransport

M = 4
BS = 512
NB = 32
BITS = 3
T = 8
FLIP_PROBS = (0.0, 1e-4, 1e-3, 1e-2)

D = NB * BS
AX = "w"


def grad_stream(t: int) -> jnp.ndarray:
    scales = jnp.asarray(
        np.geomspace(1e-3, 1.0, NB), jnp.float32)[None, :, None]
    g = (jax.random.normal(jax.random.PRNGKey(300 + t), (M, NB, BS))
         * scales)
    return g.reshape(M, D) * 0.01


def run(codec, flip_prob: float) -> dict:
    scheme = QuantScheme(name="qsgdinf", bits=BITS, bucket_size=BS)
    state = scheme.init_state()
    fm = (FaultModel(flip_prob=flip_prob, seed=17)
          if flip_prob > 0 else None)

    def one(flat, key, step):
        t = MeshTransport((AX,))
        if fm is not None:
            t = FaultyTransport(t, fm, fm.key_for_step(step))
        return quantized_allreduce(
            flat, scheme, state, key, axes=(AX,), mode="all_gather",
            use_pallas=False, transport=t, codec=codec)

    step_fn = jax.jit(jax.vmap(one, axis_name=AX,
                               in_axes=(0, None, None)))
    errs, corrupt = [], []
    for t in range(T):
        g = grad_stream(t)
        key = jax.random.fold_in(jax.random.PRNGKey(23), t)
        out, m = step_fn(g, key, jnp.int32(t))
        exact = np.asarray(g, np.float64).mean(0)
        e = float(((np.asarray(out[0], np.float64) - exact) ** 2).sum())
        errs.append(e if math.isfinite(e) else float("inf"))
        corrupt.append(float(np.asarray(m.corrupt_fraction)[0]))
    plan = codec.plan(D)
    return {
        "mean_step_err": (float(np.mean(errs))
                          if all(map(math.isfinite, errs))
                          else float("inf")),
        "max_step_err": max(errs),
        "mean_corrupt_fraction": float(np.mean(corrupt)),
        "bits_per_coord": float(plan.bits_per_coord),
    }


def main():
    scheme = QuantScheme(name="qsgdinf", bits=BITS, bucket_size=BS)
    base = codec_for_scheme(scheme)
    codecs = {"bare": base,
              "integrity": dataclasses.replace(base, integrity=True)}
    results: dict = {k: {} for k in codecs}
    for name, codec in codecs.items():
        for p in FLIP_PROBS:
            r = run(codec, p)
            results[name][f"flip_{p:g}"] = r
            common.emit(
                f"faults_{name}_p{p:g}", 0.0,
                f"err={r['mean_step_err']:.4g} "
                f"corrupt={r['mean_corrupt_fraction']:.4f}")

    # protection overhead: exactly one checksum word per bucket
    overhead = (results["integrity"]["flip_0"]["bits_per_coord"]
                - results["bare"]["flip_0"]["bits_per_coord"])
    assert abs(overhead - 32.0 / BS) < 1e-6, overhead

    base_err = results["integrity"]["flip_0"]["mean_step_err"]
    for p in FLIP_PROBS[1:]:
        on = results["integrity"][f"flip_{p:g}"]["mean_step_err"]
        # graceful: the protected aggregate never blows up, and stays
        # within a bounded factor of the fault-free quantization error
        assert math.isfinite(on), (p, on)
        assert on < 100.0 * base_err, (p, on, base_err)
    # the bare wire is unbounded once flips are common: a corrupt norm
    # word NaNs the whole aggregate
    for p in FLIP_PROBS[2:]:
        off = results["bare"][f"flip_{p:g}"]["mean_step_err"]
        on = results["integrity"][f"flip_{p:g}"]["mean_step_err"]
        assert (not math.isfinite(off)) or off > 100.0 * on, (p, off, on)

    worst = FLIP_PROBS[-1]
    off_w = results["bare"][f"flip_{worst:g}"]["mean_step_err"]
    on_w = results["integrity"][f"flip_{worst:g}"]["mean_step_err"]
    print(f"at flip_prob={worst:g}: bare err={off_w:.4g}, "
          f"integrity err={on_w:.4g} "
          f"(fault-free {base_err:.4g}); overhead {overhead:.4f} "
          "bits/coord")

    common.write_results(
        "faults",
        config={"workers": M, "bucket_size": BS, "buckets": NB,
                "bits": BITS, "steps": T, "scheme": "qsgdinf",
                "flip_probs": list(FLIP_PROBS)},
        metrics={"codecs": results,
                 "integrity_overhead_bits_per_coord": overhead})


if __name__ == "__main__":
    main()

"""Fig. 7: bucket-size and bit-width sweeps.  Full accuracy sweeps are
GPU-weeks; we report the quantity accuracy tracks (per Fig. 4 vs Table 1):
normalized quantization variance of real model gradients, per method,
across bucket sizes and bits."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantization_variance
from repro.core.schemes import QuantScheme
from repro.dist.sync import gather_stats
from .common import emit


def run(d: int = 131072):
    g = jax.random.normal(jax.random.PRNGKey(0), (d,)) * 0.01
    gn2 = float(jnp.sum(g * g))
    for name in ("alq", "amq", "qsgdinf", "nuqsgd"):
        for bucket in (256, 1024, 8192, 16384):
            scheme = QuantScheme(name=name, bits=3, bucket_size=bucket)
            state = scheme.init_state()
            if scheme.adaptive:
                stats = jax.jit(lambda f, s=scheme: gather_stats(
                    f, s, axes=()))(g)
                state = scheme.update_state(state, stats)
            var = float(quantization_variance(
                g, state.levels, bucket_size=bucket,
                norm_type=scheme.norm_type))
            emit(f"fig7a/{name}/bucket={bucket}", 0.0,
                 f"norm_var={var/gn2:.4e}")
        for bits in (2, 3, 4, 6, 8):
            scheme = QuantScheme(name=name, bits=bits, bucket_size=8192)
            state = scheme.init_state()
            if scheme.adaptive:
                stats = jax.jit(lambda f, s=scheme: gather_stats(
                    f, s, axes=()))(g)
                state = scheme.update_state(state, stats)
            var = float(quantization_variance(
                g, state.levels, bucket_size=8192,
                norm_type=scheme.norm_type))
            emit(f"fig7b/{name}/bits={bits}", 0.0,
                 f"norm_var={var/gn2:.4e}")


if __name__ == "__main__":
    run()

"""Fig. 8: convergence of the level-update algorithms (ALQ coordinate
descent vs projection-free GD vs AMQ multiplier GD) on the same
sufficient statistics, from uniform and exponential initializations."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (TruncNormStats, alq_gd_update, alq_update,
                        amq_objective, amq_update, expected_variance,
                        exp_levels, multiplier_to_levels, uniform_levels)
from .common import emit


def run():
    stats = TruncNormStats(
        mu=jnp.asarray([0.03, 0.1, 0.25], jnp.float32),
        sigma=jnp.asarray([0.02, 0.08, 0.2], jnp.float32),
        gamma=jnp.asarray([0.5, 0.3, 0.2], jnp.float32))
    for init_name, init in (("uniform", uniform_levels(3)),
                            ("exp", exp_levels(3, 0.5))):
        psi0 = float(expected_variance(stats, init))
        for sweeps in (1, 3, 10):
            t0 = time.perf_counter()
            lv = jax.block_until_ready(
                alq_update(init, stats, sweeps=sweeps))
            us = (time.perf_counter() - t0) * 1e6
            emit(f"fig8/alq_cd/{init_name}/sweeps={sweeps}", us,
                 f"psi={float(expected_variance(stats, lv)):.4e};"
                 f"psi0={psi0:.4e}")
        for steps in (10, 50, 200):
            lv = alq_gd_update(init, stats, steps=steps)
            emit(f"fig8/alq_gd/{init_name}/steps={steps}", 0.0,
                 f"psi={float(expected_variance(stats, lv)):.4e}")
    for steps in (10, 100, 400):
        p = amq_update(jnp.float32(0.5), stats, bits=3, steps=steps)
        emit(f"fig8/amq/steps={steps}", 0.0,
             f"psi={float(amq_objective(p, stats, 3)):.4e};p={float(p):.3f}")


if __name__ == "__main__":
    run()

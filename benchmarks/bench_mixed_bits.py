"""Mixed-width vs uniform-width wire at EQUAL mean bits/coordinate.

The gradient model is deliberately heterogeneous across buckets —
per-bucket scales spanning three orders of magnitude, the layer-norm /
embedding / attention spread real flattened gradients show.  The
uniform codec spends the same wire width everywhere; ``MixedWidthCodec``
spends the same TOTAL budget where ``assign_mixed_widths`` says the
norm^2-weighted expected variance is (more levels for heavy buckets,
fewer for light ones).

Measured end to end through ``quantized_allreduce`` (all_gather mode,
M=4 logical workers under vmap, production key schedule):

  * total aggregate error ||agg - exact_mean||^2 over several seeds,
  * local encode error (SyncMetrics.quant_error),
  * actual shipped bits/coordinate from the codec plans.

Writes ``BENCH_mixed_bits.json`` (committed artifact).  The acceptance
claim of the codec layer is that at equal mean bits/coord the mixed
assignment achieves LOWER total quantization error than the uniform
baseline.
"""
from __future__ import annotations

import collections

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.codec import (
    MixedWidthCodec,
    codec_for_scheme,
    mixed_widths_from_gradient,
)
from repro.core.schemes import QuantScheme
from repro.dist import sync

M = 4
BS = 256
NB = 64            # buckets per worker
BITS = 3           # uniform baseline width == mixed mean budget
SEEDS = range(6)


def hetero_grads(seed: int) -> jnp.ndarray:
    """(M, d) gradients with geomspace per-bucket scales (3 decades)."""
    k = jax.random.PRNGKey(100 + seed)
    scales = jnp.asarray(
        np.geomspace(1e-3, 1.0, NB), jnp.float32)[None, :, None]
    g = jax.random.normal(k, (M, NB, BS)) * scales
    return g.reshape(M, NB * BS)


def allreduce_err(grads, scheme, codec, key):
    state = scheme.init_state()

    def worker(g):
        return sync.quantized_allreduce(
            g, scheme, state, key, axes=("w",), mode="all_gather",
            use_pallas=False, codec=codec)

    out, m = jax.vmap(worker, axis_name="w")(grads)
    exact = np.asarray(grads).mean(0)
    agg_err = float(((np.asarray(out)[0] - exact) ** 2).sum())
    return agg_err, float(np.asarray(m.quant_error).mean()), float(
        m.comm_bits_per_coord[0])


def main():
    scheme = QuantScheme(name="alq", bits=BITS, bucket_size=BS)
    uniform = codec_for_scheme(scheme)

    # width assignment from worker-0 stats of the first draw — the
    # exact probe-step protocol the `mixed_width` scenario runs
    widths = mixed_widths_from_gradient(hetero_grads(0)[0], scheme)
    mixed = MixedWidthCodec(bucket_size=BS, norm_type=scheme.norm_type,
                            widths=widths)

    rows = {"uniform": [], "mixed": []}
    bits = {}
    for s in SEEDS:
        grads = hetero_grads(s)
        key = jax.random.fold_in(jax.random.PRNGKey(7), s)
        for name, codec in (("uniform", uniform), ("mixed", mixed)):
            agg, qerr, b = allreduce_err(grads, scheme, codec, key)
            rows[name].append({"agg_err": agg, "quant_err": qerr})
            bits[name] = b
            common.emit(f"mixed_bits/{name}/seed{s}", 0.0,
                        f"agg_err={agg:.3e} bits={b:.3f}")

    summary = {}
    for name in rows:
        summary[name] = {
            "bits_per_coord": bits[name],
            "mean_agg_err": float(np.mean(
                [r["agg_err"] for r in rows[name]])),
            "mean_quant_err": float(np.mean(
                [r["quant_err"] for r in rows[name]])),
            "per_seed": rows[name],
        }
    summary["error_ratio_mixed_over_uniform"] = (
        summary["mixed"]["mean_agg_err"]
        / summary["uniform"]["mean_agg_err"])
    summary["width_histogram"] = dict(sorted(
        collections.Counter(int(b) for b in widths).items()))

    common.write_results(
        "mixed_bits",
        config={"workers": M, "bucket_size": BS, "buckets": NB,
                "mean_bits": BITS, "seeds": len(list(SEEDS)),
                "scheme": scheme.name,
                "scale_spread": "geomspace(1e-3, 1, nb)"},
        metrics=summary)

    assert bits["mixed"] <= bits["uniform"] + 1e-6, \
        "mixed codec exceeded the uniform wire budget"
    print(f"\nuniform: {summary['uniform']['mean_agg_err']:.4e} @ "
          f"{bits['uniform']:.3f} b/coord")
    print(f"mixed:   {summary['mixed']['mean_agg_err']:.4e} @ "
          f"{bits['mixed']:.3f} b/coord")
    print(f"ratio:   {summary['error_ratio_mixed_over_uniform']:.3f}")


if __name__ == "__main__":
    main()

"""Table 2: scaling in the number of workers M (16 / 32): aggregated-
gradient error vs SuperSGD shrinks ~1/M for unbiased schemes."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import quantize as _quantize_fn
from repro.core.schemes import QuantScheme
from .common import emit, write_results


def run(d: int = 65536):
    g = jax.random.normal(jax.random.PRNGKey(0), (d,)) * 0.01
    metrics: dict = {}
    for m in ("alq", "qsgdinf", "trn"):
        scheme = QuantScheme(name=m, bits=3, bucket_size=2048)
        state = scheme.init_state()
        if scheme.adaptive:
            from repro.dist.sync import gather_stats
            stats = jax.jit(lambda f: gather_stats(f, scheme, axes=()))(g)
            state = scheme.update_state(state, stats)
        for M in (4, 16, 32):
            def agg(key):
                ks = jax.random.split(key, M)
                qs = jax.lax.map(lambda k: _quantize_fn(
                    g, state.levels, k, bucket_size=scheme.bucket_size,
                    norm_type=scheme.norm_type), ks)
                return qs.mean(0)
            err = float(jnp.mean(jax.lax.map(
                lambda k: jnp.sum((agg(k) - g) ** 2),
                jax.random.split(jax.random.PRNGKey(1), 8))))
            emit(f"table2/{m}/M={M}", 0.0,
                 f"agg_err={err:.4e};per_worker_x_M={err*M:.4e}")
            metrics[f"{m}/M={M}"] = {"agg_err": err,
                                     "per_worker_x_M": err * M}
    write_results("scaling",
                  {"d": d, "bits": 3, "bucket_size": 2048,
                   "schemes": ["alq", "qsgdinf", "trn"],
                   "workers": [4, 16, 32]},
                  metrics)


if __name__ == "__main__":
    run()

"""Tables 5-7: per-step cost of the quantization layer itself —
encode (Pallas interpret), pack, decode, and the level update — across
bits and bucket sizes, plus the modeled wire bytes each configuration
moves (the quantity the paper's 21-36%-of-fp32 step times derive from)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.schemes import QuantScheme
from repro.dist.sync import gather_stats, maybe_update_levels
from repro.kernels import ops
from .common import emit, timeit


def run(d: int = 1 << 20):
    flat = jax.random.normal(jax.random.PRNGKey(0), (d,)) * 0.01
    for bits in (2, 3, 4, 8):
        for bucket in (1024, 8192, 16384):
            scheme = QuantScheme(name="alq", bits=bits, bucket_size=bucket)
            lv = scheme.init_state().levels
            vb = flat.reshape(-1, bucket)
            u = jax.random.uniform(jax.random.PRNGKey(1), vb.shape)

            enc = jax.jit(lambda vb, u, lv: ops.quantize_op(
                vb, u, lv, norm_type="l2", use_pallas=False))
            us_enc, (codes, norms) = timeit(enc, vb, u, lv)

            pk = jax.jit(lambda c: packing.pack_signed(
                c, scheme.num_levels))
            us_pack, packed = timeit(pk, codes)

            dec = jax.jit(lambda c, n, lv: ops.dequantize_op(
                c, n, lv, use_pallas=False))
            us_dec, _ = timeit(dec, codes, norms, lv)

            wire_bits = packing.wire_bits_for(scheme.num_levels)
            wire_bytes = packed.nbytes + norms.nbytes
            emit(f"timing/encode/bits={bits}/bucket={bucket}", us_enc,
                 f"wire_bytes={wire_bytes};vs_fp32={wire_bytes/(4*d):.3f};"
                 f"wire_bits_per_coord={wire_bits}")
            emit(f"timing/pack/bits={bits}/bucket={bucket}", us_pack, "")
            emit(f"timing/decode/bits={bits}/bucket={bucket}", us_dec, "")

    # ALQ level-update cost (paper: 0.4-0.5% of training time)
    scheme = QuantScheme(name="alq", bits=3, bucket_size=8192)
    state = scheme.init_state()
    upd = jax.jit(lambda f, s: maybe_update_levels(
        f, scheme, s, jnp.bool_(True), axes=(), use_pallas=False))
    us_upd, _ = timeit(upd, flat, state)
    emit("timing/alq_level_update", us_upd, f"d={d}")


if __name__ == "__main__":
    run()

"""Beyond-paper: the two-phase quantized allreduce — wire bytes vs the
paper's broadcast-all scheme at production worker counts, and the
variance cost of the second quantization (single-device simulation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.quantize import quantize as _quantize_fn
from repro.core.schemes import QuantScheme
from .common import emit, write_results


def run(d: int = 262144):
    g = jax.random.normal(jax.random.PRNGKey(0), (d,)) * 0.01
    scheme = QuantScheme(name="alq", bits=3, bucket_size=4096)
    lv = scheme.init_state().levels
    wire = packing.wire_bits_for(scheme.num_levels)
    metrics: dict = {"wire": {}, "variance": {}}

    for M in (16, 32, 256, 512):
        bytes_bcast = M * d * wire / 8
        bytes_2ph = 2 * d * wire / 8
        bytes_fp32_ring = 2 * d * 4
        emit(f"twophase/wire/M={M}", 0.0,
             f"broadcast_B={bytes_bcast:.3e};two_phase_B={bytes_2ph:.3e};"
             f"fp32_ring_B={bytes_fp32_ring:.3e}")
        metrics["wire"][f"M={M}"] = {
            "broadcast_bytes": bytes_bcast,
            "two_phase_bytes": bytes_2ph,
            "fp32_ring_bytes": bytes_fp32_ring,
        }

    # variance compounding: Q2(mean(Q(g_i))) vs mean(Q(g_i)).
    # Re-quantizing on the same 3-bit grid forfeits the 1/M averaging
    # (~M x compounding); dist.sync's production path therefore uses an
    # 8-bit uniform grid for phase 2 (still 13 wire bits/coord total vs
    # the broadcast scheme's M*4).
    from repro.core import uniform_levels
    M = 8
    lv8 = uniform_levels(8)

    def one(key):
        ks = jax.random.split(key, M + 2)
        qs = jax.lax.map(lambda k: _quantize_fn(
            g, lv, k, bucket_size=4096), ks[:M])
        mean1 = qs.mean(0)
        req3 = _quantize_fn(mean1, lv, ks[M], bucket_size=4096)
        req8 = _quantize_fn(mean1, lv8, ks[M + 1], bucket_size=4096)
        return (jnp.sum((mean1 - g) ** 2), jnp.sum((req3 - g) ** 2),
                jnp.sum((req8 - g) ** 2))

    e1, e3, e8 = jax.lax.map(one, jax.random.split(jax.random.PRNGKey(1), 6))
    emit("twophase/variance", 0.0,
         f"one_phase_err={float(e1.mean()):.4e};"
         f"requant3bit_err={float(e3.mean()):.4e}"
         f"(x{float(e3.mean()/e1.mean()):.1f});"
         f"requant8bit_err={float(e8.mean()):.4e}"
         f"(x{float(e8.mean()/e1.mean()):.2f})")
    metrics["variance"] = {
        "one_phase_err": float(e1.mean()),
        "requant3bit_err": float(e3.mean()),
        "requant8bit_err": float(e8.mean()),
        "requant3bit_blowup": float(e3.mean() / e1.mean()),
        "requant8bit_blowup": float(e8.mean() / e1.mean()),
    }
    write_results(
        "twophase",
        {"d": d, "scheme": scheme.name, "bits": scheme.bits,
         "bucket_size": scheme.bucket_size, "variance_M": M},
        metrics)


if __name__ == "__main__":
    run()

"""Figs. 4/5 (+Fig. 12): quantization variance per method, measured on
the gradients of a real (small) model along its own optimization
trajectory ("Variance") and along a fixed fp32 trajectory ("Variance
(no train)") — the paper's decoupled comparison."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core import quantization_variance
from repro.core.schemes import QuantScheme
from .common import SimWorkers, emit

METHODS = ["alq", "alq_n", "alq_inf", "amq", "amq_n", "qsgdinf",
           "nuqsgd", "trn"]


def run(steps: int = 16):
    # 1) fixed fp32 trajectory (Fig. 5: "no train"): collect gradients
    ref = SimWorkers(QuantScheme(name="fp32"), M=2, seed=0)
    ref.run(steps)

    # exact per-method expected variance (Eq. 1-2 closed form) on the
    # final-trajectory gradient of the fp32 run
    from repro.train.data import DataConfig, Pipeline
    from jax.sharding import PartitionSpec as P
    model, mesh = ref.model, ref.mesh
    pspecs = model.param_specs()

    def grad_flat(params, ids, labels):
        g = jax.grad(lambda p: model.loss(
            p, {"ids": ids, "labels": labels}))(params)
        return ravel_pytree(g)[0]

    gf = jax.jit(jax.shard_map(
        grad_flat, mesh=mesh, in_specs=(pspecs, P("data"), P("data")),
        out_specs=P(), check_vma=False))
    b = ref.pipe.batch(999)
    with jax.set_mesh(mesh):
        flat = gf(ref.params, b["ids"], b["labels"])

    for m in METHODS:
        scheme = QuantScheme(name=m, bits=3, bucket_size=1024)
        state = scheme.init_state()
        if scheme.adaptive:
            from repro.dist.sync import gather_stats
            stats = jax.jit(lambda f: gather_stats(f, scheme, axes=()))(flat)
            state = scheme.update_state(state, stats)
        var = float(quantization_variance(
            flat, state.levels, bucket_size=scheme.bucket_size,
            norm_type=scheme.norm_type))
        gnorm2 = float(jnp.sum(flat * flat))
        emit(f"variance_no_train/{m}", 0.0,
             f"normalized_var={var / gnorm2:.4e}")

    # 2) per-method trained trajectory (Fig. 4): quantization error while
    # the method itself drives the optimization
    for m in METHODS:
        sw = SimWorkers(QuantScheme(name=m, bits=3, bucket_size=1024),
                        M=2, seed=0)
        metr = sw.run(steps)
        emit(f"variance_train/{m}", 0.0,
             f"final_qerr={np.mean(metr['qerr'][-3:]):.4e};"
             f"final_loss={np.mean(metr['loss'][-3:]):.4f}")


if __name__ == "__main__":
    run()

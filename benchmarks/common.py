"""Shared benchmark utilities: timing, CSV output, a tiny train loop
that simulates M data-parallel workers on one device (the paper's own
evaluation protocol, Sec. 5: "simulate training with 4 GPUs on a single
GPU by quantizing and dequantizing the gradient from 4 mini-batches")."""
from __future__ import annotations

import datetime
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core.quantize import quantize as _quantize_fn
from repro.core.schemes import QuantScheme
from repro.dist.sync import gather_stats
from repro.models import Model
from repro.train.data import DataConfig, Pipeline
from repro.train.optim import OptimConfig, apply_updates, init_opt_state

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def write_results(name: str, config: dict, metrics: dict) -> str:
    """Persist one benchmark run as ``BENCH_<name>.json`` at the repo
    root so successive runs leave a machine-readable perf trajectory.

    Schema: ``{name, config, metrics, timestamp}`` — ``config`` is the
    benchmark's parameterization, ``metrics`` its measured numbers (any
    JSON-serializable nesting; np/jnp scalars are coerced).
    """
    def coerce(x):
        if isinstance(x, dict):
            return {k: coerce(v) for k, v in x.items()}
        if isinstance(x, (list, tuple)):
            return [coerce(v) for v in x]
        if isinstance(x, (np.generic, jnp.ndarray, np.ndarray)):
            arr = np.asarray(x)
            return arr.item() if arr.ndim == 0 else arr.tolist()
        return x

    payload = {
        "name": name,
        "config": coerce(config),
        "metrics": coerce(metrics),
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(),
    }
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path}", flush=True)
    return path


def timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


class SimWorkers:
    """Paper-protocol simulation: M workers on one device.

    Each step draws M mini-batches, computes M local gradients, applies
    the scheme's ENCODE/DECODE to each, averages, and takes an SGD step.
    Levels adapt on the configured milestones from merged bucket stats.
    """

    def __init__(self, scheme: QuantScheme, M: int = 4, seed: int = 0,
                 lr: float = 1e-3, seq_len: int = 64, batch: int = 4,
                 arch: str = "paper-proxy"):
        self.scheme = scheme
        self.M = M
        cfg = configs.get_config(arch)
        self.cfg = cfg
        self.mesh = jax.make_mesh((1, 1), ("data", "model"))
        self.model = Model(cfg, tp=1, dp=1)
        self.pipe = Pipeline(DataConfig(
            kind="markov", vocab_size=cfg.vocab_size, seq_len=seq_len,
            global_batch=batch * M, seed=seed))
        self.ocfg = OptimConfig(name="adamw", lr=lr, weight_decay=0.0)
        with jax.set_mesh(self.mesh):
            self.params = self.model.init(jax.random.PRNGKey(seed))
        self.opt = init_opt_state(self.ocfg, self.params)
        self.state = scheme.init_state()
        self._build()

    def _build(self):
        model, scheme, M = self.model, self.scheme, self.M
        pspecs = model.param_specs()
        from jax.flatten_util import ravel_pytree

        def step(params, opt_mu, opt_nu, opt_count, levels, ids, labels,
                 key, do_update):
            def worker_grad(w):
                sl = lambda a: jax.lax.dynamic_slice_in_dim(
                    a, w * (ids.shape[0] // M), ids.shape[0] // M)
                l, g = jax.value_and_grad(
                    lambda p: model.loss(p, {"ids": sl(ids),
                                             "labels": sl(labels)}))(params)
                return l, g

            def one(w):
                l, g = worker_grad(w)
                flat, unravel = ravel_pytree(g)
                if scheme.quantized:
                    q = _quantize_fn(
                        flat, levels, jax.random.fold_in(key, w),
                        bucket_size=scheme.bucket_size,
                        norm_type=scheme.norm_type)
                else:
                    q = flat
                qerr = jnp.sum((q - flat) ** 2)
                return l, q, qerr, flat

            losses, qs, qerrs, flats = jax.lax.map(
                one, jnp.arange(M))
            mean_flat = qs.mean(0)

            # level adaptation from worker-0 stats (replicated protocol)
            new_levels = levels
            if scheme.adaptive:
                def upd(_):
                    stats = gather_stats(flats[0], scheme, axes=())
                    return scheme.update_state(
                        type(self.state)(levels, jnp.float32(0.5),
                                         jnp.int32(0)), stats).levels
                new_levels = jax.lax.cond(do_update, upd,
                                          lambda _: levels, None)

            _, unravel = ravel_pytree(params)
            grads = unravel(mean_flat)
            from repro.train.optim import OptState
            new_params, new_opt = apply_updates(
                self.ocfg, params, grads,
                OptState(opt_mu, opt_nu, opt_count))
            return (new_params, new_opt.mu, new_opt.nu, new_opt.count,
                    new_levels, losses.mean(), qerrs.mean(),
                    jnp.sum(mean_flat ** 2))

        smapped = jax.shard_map(
            step, mesh=self.mesh,
            in_specs=(pspecs, pspecs, pspecs, P(), P(), P("data"),
                      P("data"), P(), P()),
            out_specs=(pspecs, pspecs, pspecs, P(), P(), P(), P(), P()),
            check_vma=False)
        self._step = jax.jit(smapped)

    def run(self, steps: int, update_at=(2, 10)):
        metrics = {"loss": [], "qerr": []}
        levels = self.state.levels
        mu, nu, cnt = self.opt.mu, self.opt.nu, self.opt.count
        params = self.params
        with jax.set_mesh(self.mesh):
            for t in range(steps):
                b = self.pipe.batch(t)
                (params, mu, nu, cnt, levels, loss, qerr, _) = self._step(
                    params, mu, nu, cnt, levels, b["ids"], b["labels"],
                    jax.random.fold_in(jax.random.PRNGKey(1234), t),
                    jnp.bool_(t in update_at))
                metrics["loss"].append(float(loss))
                metrics["qerr"].append(float(qerr))
        self.params = params
        self.levels = levels
        return metrics

    def eval_accuracy(self, n_batches=4):
        """Next-token accuracy on held-out batches (val-acc proxy)."""
        model = self.model
        pspecs = model.param_specs()
        from repro.models.layers import lm_head_logits, rms_norm

        def acc_fn(params, ids, labels):
            x, _ = model.forward(params, ids)
            x = rms_norm(x, params["final_norm"], model.cfg.norm_eps)
            # greedy over full sequence: project all positions
            B, S, d = x.shape
            logits = lm_head_logits(model.ctx,
                                    params["lm_head"].squeeze(0),
                                    x.reshape(B * S, d),
                                    model.cfg.vocab_size)
            pred = jnp.argmax(logits, -1).reshape(B, S)
            return jnp.mean((pred == labels).astype(jnp.float32))

        f = jax.jit(jax.shard_map(
            acc_fn, mesh=self.mesh,
            in_specs=(pspecs, P("data"), P("data")), out_specs=P(),
            check_vma=False))
        accs = []
        with jax.set_mesh(self.mesh):
            for t in range(10_000, 10_000 + n_batches):
                b = self.pipe.batch(t)
                accs.append(float(f(self.params, b["ids"], b["labels"])))
        return float(np.mean(accs))

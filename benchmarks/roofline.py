"""Roofline table from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
prints, per (arch x shape x mesh): the three roofline terms in seconds,
the dominant bottleneck, MODEL_FLOPS / HLO_FLOPS, and per-device memory.
"""
from __future__ import annotations

import glob
import json
import os

from .common import emit

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load(dryrun_dir=DRYRUN_DIR):
    recs = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def run():
    recs = load()
    if not recs:
        emit("roofline/missing", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun")
        return
    for r in recs:
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("tag"):
            name += f"/{r['tag']}"
        if not r["ok"]:
            emit(name, 0.0, f"FAILED={r['error'][:80]}")
            continue
        ro = r["roofline"]
        emit(name, 0.0,
             f"compute_s={ro['compute_s']:.4e};"
             f"memory_s={ro['memory_s']:.4e};"
             f"collective_s={ro['collective_s']:.4e};"
             f"dominant={ro['dominant']};"
             f"useful_flops={r.get('useful_flops_ratio', 0):.3f};"
             f"mem_GiB={r['bytes_per_device']['total']/2**30:.1f}")


if __name__ == "__main__":
    run()

# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure.

  bench_accuracy            Table 1 (+Table 4): methods vs SuperSGD, M=4
  bench_scaling             Table 2: M = 4 / 16 / 32
  bench_variance            Figs. 4, 5, 12: quantization variance
  bench_level_convergence   Fig. 8: ALQ-CD vs GD vs AMQ
  bench_codelength          Thm 3 / App. D: bits per coordinate
  bench_hparams             Fig. 7: bucket-size x bits sweeps
  bench_timing              Tables 5-7: encode/pack/decode/update cost
  bench_twophase            beyond-paper: two-phase allreduce
  roofline                  dry-run roofline table (deliverable g)
"""
import sys

from . import (bench_accuracy, bench_codelength, bench_hparams,
               bench_level_convergence, bench_scaling, bench_timing,
               bench_twophase, bench_variance, roofline)

ALL = {
    "timing": bench_timing.run,
    "codelength": bench_codelength.run,
    "level_convergence": bench_level_convergence.run,
    "hparams": bench_hparams.run,
    "scaling": bench_scaling.run,
    "twophase": bench_twophase.run,
    "variance": bench_variance.run,
    "accuracy": bench_accuracy.run,
    "roofline": roofline.run,
}


def main() -> None:
    which = sys.argv[1:] or list(ALL)
    print("name,us_per_call,derived")
    for name in which:
        ALL[name]()


if __name__ == '__main__':
    main()

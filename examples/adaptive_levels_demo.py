"""Fig. 1 / Fig. 6 demo: the gradient-magnitude distribution drifts over
training, and ALQ's levels track it while fixed grids do not.  Prints the
average variance of normalized coordinates per phase (Fig. 1) and the
final level grids per method (Fig. 6).

  PYTHONPATH=src python examples/adaptive_levels_demo.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (TruncNormStats, alq_update, amq_update,
                        expected_variance, exp_levels,
                        multiplier_to_levels, uniform_levels)

# a drifting gradient distribution (as in Fig. 1: sharp change early,
# then steps at each LR drop)
phases = [
    ("epoch0", 0.30, 0.20),
    ("early",  0.08, 0.07),
    ("post-lr-drop-1", 0.03, 0.03),
    ("post-lr-drop-2", 0.015, 0.015),
]

print(f"{'phase':16s} {'mean(r)':>8s} {'Psi(uniform)':>13s} "
      f"{'Psi(ALQ)':>10s} {'Psi(AMQ)':>10s}")
lv_alq = uniform_levels(3)
p_amq = jnp.float32(0.5)
for name, mu, sig in phases:
    stats = TruncNormStats(mu=jnp.asarray([mu], jnp.float32),
                           sigma=jnp.asarray([sig], jnp.float32),
                           gamma=jnp.asarray([1.0], jnp.float32))
    lv_alq = alq_update(lv_alq, stats, sweeps=10)
    p_amq = amq_update(p_amq, stats, bits=3, steps=200)
    psi_u = float(expected_variance(stats, uniform_levels(3)))
    psi_a = float(expected_variance(stats, lv_alq))
    psi_m = float(expected_variance(stats, multiplier_to_levels(p_amq, 3)))
    print(f"{name:16s} {mu:8.3f} {psi_u:13.3e} {psi_a:10.3e} {psi_m:10.3e}")

print("\nfinal grids (Fig. 6):")
print("  uniform :", np.asarray(uniform_levels(3)).round(4))
print("  nuqsgd  :", np.asarray(exp_levels(3, 0.5)).round(4))
print("  ALQ     :", np.asarray(lv_alq).round(4))
print("  AMQ     :", np.asarray(multiplier_to_levels(p_amq, 3)).round(4),
      f"(p={float(p_amq):.3f})")

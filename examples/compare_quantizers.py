"""Paper Table-1 style comparison: train the same model/data/seed with
SuperSGD (fp32), ALQ, AMQ, QSGDinf, NUQSGD and TRN at 3 bits with M=4
simulated workers; print final loss + next-token accuracy per method.

  PYTHONPATH=src python examples/compare_quantizers.py [--steps 60]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
from benchmarks.common import SimWorkers
from repro.core.schemes import QuantScheme

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--M", type=int, default=4)
args = ap.parse_args()

print(f"{'method':10s} {'final loss':>10s} {'val acc':>8s}")
for m in ("fp32", "alq", "alq_n", "amq", "qsgdinf", "nuqsgd", "trn"):
    sw = SimWorkers(QuantScheme(name=m, bits=3, bucket_size=1024),
                    M=args.M, seed=0)
    metr = sw.run(args.steps, update_at=(2, 10, 30))
    acc = sw.eval_accuracy()
    print(f"{m:10s} {np.mean(metr['loss'][-5:]):10.4f} {acc:8.4f}",
          flush=True)

"""Quickstart: train a small model with adaptively quantized (ALQ, 3-bit)
data-parallel SGD on a learnable synthetic task, and watch (a) the loss
fall and (b) the quantization grid adapt to the gradient distribution.

  PYTHONPATH=src python examples/quickstart.py
"""
import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.train",
     "--arch", "paper-proxy", "--scheme", "alq", "--bits", "3",
     "--steps", "40", "--lr", "2e-3"],
    check=True)

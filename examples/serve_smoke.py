"""Serve a small model with batched requests: prefill + greedy decode
against the sequence-sharded KV cache (the decode dry-run's serve_step).

  PYTHONPATH=src python examples/serve_smoke.py
"""
import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve",
     "--arch", "mixtral-8x7b", "--batch", "2", "--prompt-len", "16",
     "--gen", "8"],
    check=True)

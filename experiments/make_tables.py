"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
JSON artifacts.  Usage: python experiments/make_tables.py [dir]"""
import glob
import json
import sys


def fmt(recs):
    recs = sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out = []
    out.append("| arch | shape | mesh | ok | micro | flops/dev | hbm B/dev "
               "| wire B/dev | compute s | memory s | collective s | "
               "dominant | useful | mem GiB/dev |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("tag"):
            continue  # perf-iteration runs rendered separately
        if not r["ok"]:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL "
                       f"| | | | | | | | | | {r['error'][:60]} |")
            continue
        ro = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r.get('microbatches', 1)} "
            f"| {ro['flops_per_device']:.2e} "
            f"| {ro['hbm_bytes_per_device']:.2e} "
            f"| {ro['collective_wire_bytes']:.2e} "
            f"| {ro['compute_s']:.2e} | {ro['memory_s']:.2e} "
            f"| {ro['collective_s']:.2e} | **{ro['dominant']}** "
            f"| {r.get('useful_flops_ratio', 0):.2f} "
            f"| {r['bytes_per_device']['total']/2**30:.1f} |")
    return "\n".join(out)


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = [json.load(open(f)) for f in glob.glob(f"{d}/*.json")]
    print(fmt(recs))

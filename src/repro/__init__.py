"""Adaptive Gradient Quantization for Data-Parallel SGD — reproduction.

Importing the package installs the jax API backfills (see _jax_compat)
before any submodule touches jax, so the whole tree runs on the pinned
jax as well as on current releases.
"""
from . import _jax_compat  # noqa: F401  (side effect: API backfills)

"""Backfills for jax APIs this codebase uses that predate the pinned jax.

The repo is written against the modern public surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.lax.axis_size``); the container pins jax 0.4.37
where those live under ``jax.experimental.shard_map`` / ``Mesh.__enter__``
or do not exist.  Importing ``repro`` installs the aliases once, so every
entry point (tests, benchmarks, subprocess scripts) sees one API.

Each shim is a no-op when the real attribute already exists, so upgrading
jax silently switches to the native implementations.
"""
from __future__ import annotations

import contextlib

import jax


def _ambient_mesh():
    from jax._src import mesh as mesh_lib

    m = mesh_lib.thread_resources.env.physical_mesh
    if m.empty:
        raise ValueError(
            "shard_map called without a mesh and no ambient mesh is set; "
            "pass mesh= or wrap the call in `with jax.set_mesh(mesh):`")
    return m


def install() -> None:
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh=None, in_specs=None, out_specs=None,
                      check_vma=None, check_rep=None, **kwargs):
            if mesh is None:
                mesh = _ambient_mesh()
            check = True
            if check_rep is not None:
                check = check_rep
            if check_vma is not None:  # renamed upstream: check_rep -> check_vma
                check = check_vma
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check, **kwargs)

        jax.shard_map = shard_map

    if not hasattr(jax, "set_mesh"):

        @contextlib.contextmanager
        def set_mesh(mesh):
            # Mesh is a context manager on 0.4.x: entering sets the
            # thread-resources physical mesh the shim above reads back.
            with mesh:
                yield mesh

        jax.set_mesh = set_mesh

    if not hasattr(jax.lax, "axis_size"):

        def axis_size(axis_name):
            if isinstance(axis_name, (tuple, list)):
                n = 1
                for ax in axis_name:
                    n *= axis_size(ax)
                return n
            # psum of a Python literal over a named axis is evaluated
            # statically (no collective is emitted).
            return jax.lax.psum(1, axis_name)

        jax.lax.axis_size = axis_size


install()

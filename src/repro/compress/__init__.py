"""repro.compress: the stateful gradient-compression algorithm zoo.

Layering (outermost first):

    CompressionAlgorithm   residual state + warmup gates   (this package)
    GradientCodec          wire layout: dense / mixed / sparse payloads
    Transport              collectives that move the packed words

Selection is a spec string, mirroring the scheme grammar used
everywhere else (``TrainConfig(compress=...)``, the ``--compress`` CLI
flag, ``Scenario(compress=(...,))``):

    "plain"      stateless passthrough (bit-exact with the raw codec path)
    "ef"         error feedback;          "ef:<warmup_steps>"
    "topk"       EF + SparseCodec at the scheme's equal-wire-budget k;
                 "topk:<k>" for an explicit kept count per bucket

See ``docs/compression.md`` for the algorithm math and the sparse wire
layout.
"""
from __future__ import annotations

from .base import CompressionAlgorithm, CompressState, EFAlgorithm
from .sparse import SparseCodec, sparse_codec_for_scheme

ALGORITHMS = ("plain", "ef", "topk")

__all__ = [
    "ALGORITHMS",
    "CompressState",
    "CompressionAlgorithm",
    "EFAlgorithm",
    "SparseCodec",
    "make_algorithm",
    "sparse_codec_for_scheme",
]


def make_algorithm(spec: str, scheme,
                   codec=None) -> CompressionAlgorithm:
    """Build an algorithm from its spec string.

    ``codec`` is the dense wire codec the algorithm should drive —
    ``plain`` and ``ef`` compose with ANY dense codec (``None`` means
    the scheme's uniform codec).  ``topk`` always builds its own
    ``SparseCodec`` (the spec's ``:k`` argument, or the
    equal-wire-budget default), so passing an explicit ``codec``
    together with ``topk`` is a config conflict and raises rather than
    silently discarding one of the two.
    """
    from repro.core.codec import codec_for_scheme

    name, _, arg = str(spec).partition(":")
    if name == "topk":
        if codec is not None:
            raise ValueError(
                "compress='topk' builds its own SparseCodec and cannot "
                f"compose with an explicit codec ({type(codec).__name__}"
                "); configure either the codec or top-k sparsification, "
                "not both")
        sparse = sparse_codec_for_scheme(
            scheme, k=int(arg) if arg else None)
        return EFAlgorithm(codec=sparse, name="topk")
    if codec is None:
        codec = codec_for_scheme(scheme)
    if name == "plain":
        return CompressionAlgorithm(codec=codec)
    if name == "ef":
        return EFAlgorithm(codec=codec,
                           warmup_steps=int(arg) if arg else 0)
    raise ValueError(
        f"unknown compression algorithm {name!r}; known: {ALGORITHMS}")

"""The compression-algorithm layer: stateful wrappers around a codec.

The codec seam (``core.codec.GradientCodec``) is stateless per step: it
owns *how bytes are laid out*, not *what goes into them across steps*.
A ``CompressionAlgorithm`` is the layer above (algorithm ⊃ codec ⊃
transport, cf. Bagua's algorithm registry): it wraps ONE codec and owns
an explicit, pytree-serializable ``CompressState`` that is threaded
through the training loop exactly like optimizer state — checkpointed,
restored, and updated once per synchronization.

The hook is deliberately tiny, so every consumer (``dist.sync`` wire
modes, the FSDP reduce-scatter backward, all ``repro.sim`` topologies)
sequences the same three calls:

    inp       = algo.prepare(flat, state)      # residual injection
    out, own  = <codec ENCODE -> collective -> DECODE>(inp)
    new_state = algo.feedback(state, inp, own) # residual update

``own`` is the worker's OWN lossy round trip Q(inp) — the decode of the
bytes it just put on the wire, which every wire mode already computes
for its quantization-error metric.  Error feedback therefore costs zero
additional wire bytes: the residual is derived entirely locally.

Shipped algorithms (see ``repro.compress.make_algorithm``):

``plain``  Stateless passthrough.  ``prepare`` is the identity and the
    state is empty, so the wire path is bit-for-bit today's path (pinned
    against the PR 3 goldens).

``ef``     Error feedback [Seide+ 14; Karimireddy+ 19]: the residual
    ``e_t`` re-injects last step's quantization error,

        inp_t   = g_t + e_t
        e_{t+1} = inp_t - Q(inp_t)

    so the *cumulative* applied update tracks the cumulative true
    gradient even at 1-2 bit grids where the per-step error is large.
    A warmup gate (``warmup_steps``) keeps the residual at zero for the
    first steps (Bagua-style warmup), letting early large-magnitude
    gradients sync uncorrected before the memory engages.

``topk``   ``ef`` composed with the sparse payload family
    (``SparseCodec``): top-k selection is biased (dropped coordinates
    are lost), so the residual memory is what makes it converge — the
    classic memory-compensated Top-k SGD [Stich+ 18].
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from repro.core.codec import GradientCodec


class CompressState(NamedTuple):
    """Per-worker algorithm state (a pytree; lives next to SchemeState).

    ``residual`` is the flat error-feedback memory over the original
    (unpadded) ``d`` coordinates — shape ``(0,)`` for stateless
    algorithms so plain cells carry no dead weight.  ``step`` drives the
    warmup gate.
    """

    residual: jnp.ndarray
    step: jnp.ndarray

    @property
    def residual_norm(self) -> jnp.ndarray:
        return jnp.sqrt(jnp.sum(self.residual.astype(jnp.float32) ** 2))


@dataclasses.dataclass(frozen=True)
class CompressionAlgorithm:
    """Base algorithm; the base class IS the ``plain`` passthrough."""

    codec: GradientCodec
    name: str = "plain"
    warmup_steps: int = 0

    @property
    def stateful(self) -> bool:
        return False

    @property
    def kept_fraction(self) -> float:
        """Fraction of coordinates on the wire (1.0 for dense codecs)."""
        return float(getattr(self.codec, "kept_fraction", 1.0))

    def init_state(self, d: int) -> CompressState:
        n = d if self.stateful else 0
        return CompressState(residual=jnp.zeros((n,), jnp.float32),
                             step=jnp.zeros((), jnp.int32))

    # -- the two hook points ---------------------------------------------

    def prepare(self, flat: jnp.ndarray,
                state: CompressState | None) -> jnp.ndarray:
        """What the codec encodes this step (residual-corrected input)."""
        return flat

    def feedback(self, state: CompressState | None, inp: jnp.ndarray,
                 own: jnp.ndarray) -> CompressState | None:
        """New state from this step's own lossy round trip Q(inp)."""
        if state is None:
            return None
        return state._replace(step=state.step + 1)

    # -- metrics ----------------------------------------------------------

    def residual_norm(self, state: CompressState | None) -> jnp.ndarray:
        if state is None or not self.stateful:
            return jnp.float32(0.0)
        return state.residual_norm


@dataclasses.dataclass(frozen=True)
class EFAlgorithm(CompressionAlgorithm):
    """Error feedback around any lossy codec (``name='topk'`` when the
    codec is the sparse family — same residual math, sparser wire)."""

    name: str = "ef"

    @property
    def stateful(self) -> bool:
        return True

    def _gate(self, state: CompressState) -> jnp.ndarray:
        return (state.step >= self.warmup_steps).astype(jnp.float32)

    def prepare(self, flat, state):
        return flat + self._gate(state) * state.residual

    def feedback(self, state, inp, own):
        # during warmup the memory stays identically zero (gate applies
        # to the WRITE too, so no error accumulates before it is used)
        residual = self._gate(state) * (inp - own)
        return CompressState(residual=residual, step=state.step + 1)

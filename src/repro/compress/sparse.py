"""SparseCodec: the sparse (top-k) wire-payload family on the codec seam.

Dense codecs (``UniformCodec`` / ``MixedWidthCodec``) ship one symbol
per coordinate.  ``SparseCodec`` ships only the ``k`` largest-magnitude
coordinates of every bucket — the QSGD-style sparsity-aware encoding
taken to its explicit form: each kept coordinate travels as a
(bit-packed bucket-local index, quantized value symbol) pair, plus the
usual packed norm side-channel.  Everything else decodes to exactly 0,
so the aggregate of M gathered streams is the *union* of the per-worker
supports (decode scatters each stream into a dense vector; the
transports' mean over streams then is the gather-style union aggregate).

The wire layout of one payload segment is

    [ value symbols: shard_nb*k symbols, wire_bits(L) each ]
    [ indices:       shard_nb*k indices, idx_bits each     ]
    [ norm words:    shard_nb packed bucket norms          ]

with both blocks independently word-aligned, so every word count — and
therefore the exact shipped bits/coordinate — is static in the
``WirePlan`` (``k`` is a static codec field).  There is NO dynamic
length anywhere: wire volume is exact by construction, which is what
lets the cluster cost model and the acceptance accounting treat sparse
payloads like any other ``WirePayload``.

Selection is per bucket: ``jax.lax.top_k`` on ``|v|`` (ties break
toward the lower index), indices re-sorted ascending so the payload is
canonical.  Kept values are quantized on the SAME adaptive grid the
dense codecs use (``levels``), with the bucket norm computed over the
kept set — for L-inf the two agree exactly (the max survives
selection); for L2 the kept-set norm is the tight normalizer for what
actually travels.

Zero buckets stay exact fixed points of ENCODE/DECODE (norm 0 ->
symbols 0 -> decode 0), so bucketize padding never leaks — the same
invariant the dense codecs guarantee.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.codec import GradientCodec, WirePayload, WirePlan


def _idx_bits(bucket_size: int) -> int:
    return max(1, math.ceil(math.log2(bucket_size)))


@dataclasses.dataclass(frozen=True)
class SparseCodec(GradientCodec):
    """Per-bucket top-k magnitude selection; index+value wire payload."""

    num_levels: int = 8   # levels of the kept-value grid (scheme grid)
    k: int = 64           # kept coordinates per bucket (static)

    def __post_init__(self):
        if not 1 <= self.k <= self.bucket_size:
            raise ValueError(
                f"k={self.k} must be in [1, bucket_size={self.bucket_size}]")

    # -- static accounting ------------------------------------------------

    @property
    def kept_fraction(self) -> float:
        return self.k / self.bucket_size

    @property
    def idx_bits(self) -> int:
        return _idx_bits(self.bucket_size)

    @property
    def _wire_bits(self) -> int:
        return packing.wire_bits_for(self.num_levels)

    @property
    def nominal_bits_per_coord(self) -> float:
        return (self.k * (self._wire_bits + self.idx_bits)
                / self.bucket_size + self._norm_bits_per_coord)

    # -- planning ---------------------------------------------------------

    def _value_words(self, snb: int) -> int:
        return packing.packed_words(snb * self.k, self._wire_bits)

    def _index_words(self, snb: int) -> int:
        return packing.packed_words(snb * self.k, self.idx_bits)

    def plan_buckets(self, nb: int, *, shards: int = 1,
                     d: int | None = None) -> WirePlan:
        if nb % shards:
            raise ValueError(f"nb={nb} not divisible by shards={shards}")
        if d is None:
            d = nb * self.bucket_size
        snb = nb // shards
        cw = self._value_words(snb) + self._index_words(snb)
        nw = packing.norm_words(snb, self.norm_dtype)
        return WirePlan(d=d, bucket_size=self.bucket_size, nb=nb,
                        shards=shards, code_words=cw, norm_words=nw,
                        widths=None,
                        bits_per_coord=32.0 * shards * (cw + nw) / d)

    # -- select + quantize (shared by encode / requantize) ----------------

    def _select(self, vb: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        """(nb, bs) -> (kept values (nb, k), ascending indices (nb, k))."""
        _, idx = jax.lax.top_k(jnp.abs(vb), self.k)
        idx = jnp.sort(idx, axis=1)
        return jnp.take_along_axis(vb, idx, axis=1), idx

    def _quantize_kept(self, sel, levels, key, use_pallas):
        from repro.kernels import ops
        u = jax.random.uniform(key, sel.shape, jnp.float32)
        return ops.quantize_op(sel, u, levels, norm_type=self.norm_type,
                               use_pallas=use_pallas)

    # -- value <-> wire ---------------------------------------------------

    def encode(self, vb, levels, key, plan, *, use_pallas=True):
        sel, idx = self._select(vb)
        codes, norms = self._quantize_kept(sel, levels, key, use_pallas)
        L = levels.shape[0]
        snb = plan.shard_nb

        def seg_words(c, i):
            return jnp.concatenate([packing.pack_signed(c, L),
                                    packing.pack(i, self.idx_bits)])

        if plan.shards == 1:
            return WirePayload(
                words=seg_words(codes, idx),
                norm_words=packing.pack_norms(norms, self.norm_dtype))
        words = jnp.stack([
            seg_words(
                jax.lax.slice_in_dim(codes, j * snb, (j + 1) * snb),
                jax.lax.slice_in_dim(idx, j * snb, (j + 1) * snb))
            for j in range(plan.shards)])
        nwords = jax.vmap(
            lambda x: packing.pack_norms(x, self.norm_dtype))(
                norms.reshape(plan.shards, snb))
        return WirePayload(words=words, norm_words=nwords)

    def decode(self, payload, levels, plan, *, shard=None,
               use_pallas=True):
        # Every segment has the SAME static layout (k is uniform), so the
        # shard argument needs no lax.switch dispatch: any stream decodes
        # with one code path regardless of which segment it carries.
        from repro.kernels import ops
        words, nwords = payload
        single = words.ndim == 1
        if single:
            words, nwords = words[None], nwords[None]
        snb = plan.shard_nb
        bs = self.bucket_size
        vw = self._value_words(snb)
        M = words.shape[0]
        norms = jax.vmap(
            lambda w: packing.unpack_norms(w, snb, self.norm_dtype))(nwords)
        L = levels.shape[0]
        sym = jax.vmap(lambda w: packing.unpack_signed(
            w[:vw], snb * self.k, L))(words)
        idx = jax.vmap(lambda w: packing.unpack(
            w[vw:], snb * self.k, self.idx_bits))(words)
        vals = ops.dequantize_op(
            sym.reshape(M * snb, self.k), norms.reshape(-1), levels,
            use_pallas=use_pallas)                       # (M*snb, k)
        idx = jnp.minimum(idx.reshape(M * snb, self.k), bs - 1)
        rows = jnp.arange(M * snb)[:, None]
        dense = jnp.zeros((M * snb, bs), jnp.float32).at[rows, idx].set(vals)
        dense = dense.reshape(M, snb * bs)
        return dense[0] if single else dense

    def requantize(self, vb, levels, key, plan, *, chunk=0,
                   use_pallas=True):
        from repro.kernels import ops
        sel, idx = self._select(vb)
        codes, norms = self._quantize_kept(sel, levels, key, use_pallas)
        wn = packing.unpack_norms(
            packing.pack_norms(norms, self.norm_dtype), norms.shape[0],
            self.norm_dtype)
        vals = ops.dequantize_op(codes, wn, levels, use_pallas=use_pallas)
        rows = jnp.arange(vb.shape[0])[:, None]
        return jnp.zeros_like(vb).at[rows, idx].set(vals)


def sparse_codec_for_scheme(scheme, k: int | None = None) -> SparseCodec:
    """The scheme's sparse codec; ``k=None`` picks the *equal-wire-budget*
    default: the largest k whose index+value cost fits the scheme's dense
    fixed-width symbol budget, ``k = floor(bs * wb / (wb + idx_bits))`` —
    so ``topk`` and ``plain`` ship the same nominal bits/coordinate out
    of the box."""
    wb = packing.wire_bits_for(scheme.num_levels)
    if k is None:
        k = max(1, (scheme.bucket_size * wb)
                // (wb + _idx_bits(scheme.bucket_size)))
    return SparseCodec(num_levels=scheme.num_levels,
                       bucket_size=scheme.bucket_size,
                       norm_type=scheme.norm_type,
                       norm_dtype=scheme.norm_dtype, k=int(k))

"""Architecture registry: one module per assigned architecture."""
from . import (
    granite_3_2b,
    jamba_1_5_large_398b,
    llama3_2_1b,
    llama4_scout_17b_a16e,
    llama_3_2_vision_11b,
    mixtral_8x7b,
    musicgen_large,
    paper_mlp,
    qwen1_5_32b,
    qwen3_0_6b,
    rwkv6_7b,
)
from .shapes import SHAPES, InputShape, input_specs

_MODULES = {
    "rwkv6-7b": rwkv6_7b,
    "qwen1.5-32b": qwen1_5_32b,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "qwen3-0.6b": qwen3_0_6b,
    "llama-3.2-vision-11b": llama_3_2_vision_11b,
    "musicgen-large": musicgen_large,
    "mixtral-8x7b": mixtral_8x7b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "granite-3-2b": granite_3_2b,
    "llama3.2-1b": llama3_2_1b,
    "paper-proxy": paper_mlp,
}

ARCH_NAMES = [n for n in _MODULES if n != "paper-proxy"]


def get_config(name: str):
    return _MODULES[name].CONFIG


def get_smoke_config(name: str):
    return _MODULES[name].SMOKE

"""Granite-3.0-2B — dense, GQA kv=8. [hf:ibm-granite/granite-3.0-2b-base]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b", arch_type="dense",
    num_layers=40, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=49155,
    rope_theta=1e4,
    source="hf:ibm-granite/granite-3.0-2b-base",
)

SMOKE = ModelConfig(
    name="granite-smoke", arch_type="dense",
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    d_ff=512, vocab_size=509,   # deliberately non-tp-divisible (padding path)
    compute_dtype="float32",
    source="reduced granite-3-2b",
)

"""Jamba-1.5-Large 398B — hybrid Mamba+attention 7:1, MoE 16e top-2
every other layer. [arXiv:2403.19887]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", arch_type="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    layer_pattern="mamba_hybrid", attn_every=8,
    moe=True, num_experts=16, top_k=2, moe_every=2,
    mamba_d_state=16, mamba_conv=4, mamba_expand=2,
    # 398B params on <=512 chips only fit with bf16 params + bf16 momentum
    # (398e9 * 6B / 256 = 9.3 GB/chip); noted in EXPERIMENTS.md.
    param_dtype="bfloat16",
    source="arXiv:2403.19887 (Jamba); 1.5-Large dims per assignment",
)

SMOKE = ModelConfig(
    name="jamba-smoke", arch_type="hybrid",
    num_layers=8, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512,
    layer_pattern="mamba_hybrid", attn_every=8,
    moe=True, num_experts=4, top_k=2, moe_every=2,
    mamba_d_state=8, mamba_conv=4, mamba_expand=2,
    compute_dtype="float32",
    source="reduced jamba-1.5-large",
)

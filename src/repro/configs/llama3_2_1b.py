"""Llama-3.2-1B — small dense llama3, GQA kv=8.
[hf:meta-llama/Llama-3.2-1B]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", arch_type="dense",
    num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=128256,
    rope_theta=5e5,
    source="hf:meta-llama/Llama-3.2-1B",
)

SMOKE = ModelConfig(
    name="llama3.2-smoke", arch_type="dense",
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    d_ff=512, vocab_size=512,
    compute_dtype="float32",
    source="reduced llama3.2-1b",
)

"""Llama-4-Scout 17B-active / 16 experts top-1 + shared expert, chunked
attention (3 of 4 layers, chunk 8192) with full attention every 4th
(iRoPE). Early-fusion multimodal — text backbone here, frontends stubbed.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", arch_type="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    attn_kind="chunked", chunk=8192, full_attn_every=4,
    moe=True, num_experts=16, top_k=1, shared_expert=True,
    rope_theta=5e5,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (MoE top-1, chunked attn)",
)

SMOKE = ModelConfig(
    name="llama4-smoke", arch_type="moe",
    num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512,
    attn_kind="chunked", chunk=64, full_attn_every=4,
    moe=True, num_experts=4, top_k=1, shared_expert=True,
    compute_dtype="float32",
    source="reduced llama4-scout",
)

"""Llama-3.2-Vision-11B — dense decoder with gated cross-attention image
layers every 5th layer; vision frontend stubbed (precomputed patch
embeddings). [hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", arch_type="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    cross_attn_every=5, num_image_tokens=1601,
    rope_theta=5e5,
    source="hf:meta-llama/Llama-3.2-11B-Vision (cross-attn every 5th)",
)

SMOKE = ModelConfig(
    name="llama-vision-smoke", arch_type="vlm",
    num_layers=5, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512,
    cross_attn_every=5, num_image_tokens=16,
    compute_dtype="float32",
    source="reduced llama-3.2-vision-11b",
)

"""Mixtral-8x7B — MoE 8 experts top-2, GQA kv=8, sliding-window attention.
[arXiv:2401.04088]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", arch_type="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    attn_kind="sliding", window=4096,
    moe=True, num_experts=8, top_k=2,
    rope_theta=1e6,
    source="arXiv:2401.04088 (Mixtral of Experts)",
)

SMOKE = ModelConfig(
    name="mixtral-smoke", arch_type="moe",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512,
    attn_kind="sliding", window=64,
    moe=True, num_experts=4, top_k=2,
    compute_dtype="float32",
    source="reduced mixtral-8x7b",
)

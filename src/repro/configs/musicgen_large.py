"""MusicGen-Large — decoder-only transformer over EnCodec audio tokens;
the codec frontend is stubbed (token ids arrive precomputed).
[arXiv:2306.05284]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", arch_type="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    rope_theta=1e4,
    source="arXiv:2306.05284 (MusicGen; decoder over EnCodec tokens)",
)

SMOKE = ModelConfig(
    name="musicgen-smoke", arch_type="audio",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=512, vocab_size=512,
    compute_dtype="float32",
    source="reduced musicgen-large",
)

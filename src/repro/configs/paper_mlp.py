"""The paper's own experimental scale, transposed to this codebase: a
small dense model trained on CPU for the Table-1/Fig-4 style benchmarks
(the paper used ResNet-32/110 on CIFAR; the quantizer is model-agnostic
so fidelity experiments here use a small member of the assigned
transformer family — see DESIGN.md §6.5)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-proxy", arch_type="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=256,
    compute_dtype="float32",
    source="paper Sec. 5 scale proxy",
)

SMOKE = CONFIG

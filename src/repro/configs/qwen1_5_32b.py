"""Qwen1.5-32B — dense, GQA kv=40 (full MHA kv), QKV bias.
[hf:Qwen/Qwen1.5-0.5B family card, scaled per assignment]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", arch_type="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=27392, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6,
    source="hf:Qwen/Qwen1.5-0.5B (QKV bias; dims per assignment)",
)

SMOKE = ModelConfig(
    name="qwen1.5-smoke", arch_type="dense",
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=8,
    d_ff=512, vocab_size=512, qkv_bias=True,
    compute_dtype="float32",
    source="reduced qwen1.5-32b",
)

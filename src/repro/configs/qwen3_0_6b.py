"""Qwen3-0.6B — dense, GQA kv=8, qk_norm. [hf:Qwen/Qwen3-8B family]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", arch_type="dense",
    num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=3072, vocab_size=151936,
    qk_norm=True, head_dim=128, rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B (qk_norm, GQA; 0.6B dims per assignment)",
)

SMOKE = ModelConfig(
    name="qwen3-smoke", arch_type="dense",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, qk_norm=True, head_dim=64,
    compute_dtype="float32",
    source="reduced qwen3-0.6b",
)

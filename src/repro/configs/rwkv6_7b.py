"""RWKV6 "Finch" 7B — attention-free, data-dependent decay.
[arXiv:2404.05892]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", arch_type="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536,
    layer_pattern="rwkv", rwkv_head_dim=64,
    source="arXiv:2404.05892 (RWKV-5/6: Eagle and Finch)",
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", arch_type="ssm",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=512, vocab_size=512,
    layer_pattern="rwkv", rwkv_head_dim=64,
    compute_dtype="float32",
    source="reduced rwkv6-7b",
)

"""The four assigned input shapes and their ShapeDtypeStruct stand-ins."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (global shapes,
    no device allocation — the dry-run pattern)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "ids": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    elif shape.kind == "prefill":
        specs = {"ids": jax.ShapeDtypeStruct((B, S), i32)}
    else:  # decode: one new token + positions; cache supplied separately
        specs = {
            "token": jax.ShapeDtypeStruct((B,), i32),
            "pos": jax.ShapeDtypeStruct((B,), i32),
        }
    if cfg.cross_attn_every and shape.kind != "decode":
        specs["vision"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    elif cfg.cross_attn_every:
        specs["vision"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    return specs

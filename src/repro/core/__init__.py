"""Core of the paper's contribution: adaptive gradient quantization."""
from .levels import (
    exp_levels,
    is_feasible,
    level_gaps,
    multiplier_to_levels,
    num_inner,
    num_levels,
    ternary_levels,
    uniform_levels,
)
from .quantize import (
    NORM_L1,
    NORM_L2,
    NORM_LINF,
    QuantizedTensor,
    bucket_norm,
    code_dtype,
    decode,
    encode,
    normalized_magnitudes,
    pad_to_buckets,
    quantization_variance,
    quantize,
    stochastic_round,
)
from .stats import (
    TruncNormStats,
    expected_variance,
    fit_bucket_stats,
    merge_stats,
    stats_from_moments,
    mixture_cdf,
    mixture_inverse_cdf,
    mixture_pdf,
    partial_moment0,
    partial_moment1,
    partial_moment2,
)
from .adapt import alq_gd_update, alq_update, amq_gradient, amq_objective, amq_update, psi_gradient
from .codec import (
    GradientCodec,
    MixedWidthCodec,
    UniformCodec,
    WirePayload,
    WirePlan,
    assign_mixed_widths,
    codec_for_scheme,
    make_codec,
    mixed_widths_from_gradient,
    requant_codec,
    resample_levels,
)
from .coding import (
    code_length_bound,
    entropy_bits,
    expected_bits_per_coordinate,
    expected_huffman_bits,
    huffman_code_lengths,
    level_probabilities,
)
from .packing import (
    norm_words,
    pack,
    pack_norms,
    pack_signed,
    packed_words,
    unpack,
    unpack_norms,
    unpack_signed,
    wire_bits_for,
)
from .schemes import ALL_SCHEMES, QuantScheme, SchemeState, default_update_schedule

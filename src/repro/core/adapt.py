"""Adaptive level updates: ALQ (coordinate descent), projection-free GD,
and AMQ (exponential-multiplier gradient descent).

All updates consume a ``TruncNormStats`` mixture (the sufficient
statistics of Algorithm 1) and are closed-form in (Phi, phi) plus
bisection searches, so they are cheap, deterministic, and identical on
every processor — no extra synchronization is needed beyond the stats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .levels import level_gaps, multiplier_to_levels
from .stats import (
    TruncNormStats,
    expected_variance,
    mixture_cdf,
    partial_moment0,
    partial_moment1,
)


# ---------------------------------------------------------------------------
# ALQ: coordinate descent (Thm 1 / Eqs. 4-5, App. C.1)
# ---------------------------------------------------------------------------

def _cd_target(stats: TruncNormStats, a, c):
    """RHS of Eq. (4): F(c) - int_a^c (r-a)/(c-a) dF(r)."""
    m1 = partial_moment1(stats, a, c)
    m0 = partial_moment0(stats, a, c)
    frac = (m1 - a * m0) / jnp.maximum(c - a, 1e-12)
    return mixture_cdf(stats, c) - frac


def _bisect_cdf(stats: TruncNormStats, target, lo, hi, iters: int = 40):
    """Solve F(x) = target for x in [lo, hi] (F is monotone)."""

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        below = mixture_cdf(stats, mid) < target
        return jnp.where(below, mid, lo), jnp.where(below, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


@functools.partial(jax.jit, static_argnames=("sweeps", "bisect_iters"))
def alq_update(
    levels: jnp.ndarray,
    stats: TruncNormStats,
    *,
    sweeps: int = 10,
    bisect_iters: int = 40,
) -> jnp.ndarray:
    """ALQ: sequential CD sweeps over interior levels (Eq. 5).

    Each sub-problem min_{l_j} Psi is convex (Prop. 2); the update is the
    closed form l_j* = F^{-1}(F(l_{j+1}) - int (r - l_{j-1})/(l_{j+1} -
    l_{j-1}) dF) solved by bisection on [l_{j-1}, l_{j+1}].  CD keeps
    l in the feasible set without projection.  Converges in < 10 sweeps
    in practice (paper Sec. 3.1).
    """
    s = levels.shape[0] - 2
    if s <= 0:
        return levels  # ternary etc.: nothing to adapt

    def one_level(j, lv):
        a, c = lv[j - 1], lv[j + 1]
        target = _cd_target(stats, a, c)
        new = _bisect_cdf(stats, target, a, c, iters=bisect_iters)
        # guard strict monotonicity under fp
        new = jnp.clip(new, a + 1e-7, c - 1e-7)
        return lv.at[j].set(new)

    def sweep(_, lv):
        return jax.lax.fori_loop(1, s + 1, one_level, lv)

    return jax.lax.fori_loop(0, sweeps, sweep, levels)


# ---------------------------------------------------------------------------
# Projection-free gradient descent (Eqs. 6-7, App. C.2)
# ---------------------------------------------------------------------------

def psi_gradient(levels: jnp.ndarray, stats: TruncNormStats) -> jnp.ndarray:
    """dPsi/dl_j = int_{l_{j-1}}^{l_j} (r - l_{j-1}) dF
                  - int_{l_j}^{l_{j+1}} (l_{j+1} - r) dF   (Eq. 6)."""
    a = levels[:-2]   # l_{j-1}
    b = levels[1:-1]  # l_j
    c = levels[2:]    # l_{j+1}
    left = partial_moment1(stats, a, b) - a * partial_moment0(stats, a, b)
    right = c * partial_moment0(stats, b, c) - partial_moment1(stats, b, c)
    return left - right


@functools.partial(jax.jit, static_argnames=("steps",))
def alq_gd_update(
    levels: jnp.ndarray,
    stats: TruncNormStats,
    *,
    lr: float = 0.5,
    steps: int = 50,
) -> jnp.ndarray:
    """ALQG: projection-free GD — step clipped to delta_j/2 (Eq. 7)."""
    if levels.shape[0] <= 2:
        return levels

    def body(_, lv):
        g = psi_gradient(lv, stats)
        delta = level_gaps(lv)
        step = jnp.sign(g) * jnp.minimum(lr * jnp.abs(g), delta / 2.0)
        return lv.at[1:-1].set(lv[1:-1] - step)

    return jax.lax.fori_loop(0, steps, body, levels)


# ---------------------------------------------------------------------------
# AMQ: exponential levels, single multiplier p (Sec. 3.3 / App. C.3)
# ---------------------------------------------------------------------------

def amq_objective(p: jnp.ndarray, stats: TruncNormStats, bits: int) -> jnp.ndarray:
    """Psi(p) for levels [0, p^s, ..., p, 1] (Eq. 32 restricted to [0,1])."""
    return expected_variance(stats, multiplier_to_levels(p, bits))


def amq_gradient(p: jnp.ndarray, stats: TruncNormStats, bits: int) -> jnp.ndarray:
    """Closed-form dPsi/dp (Eq. 8 / App. C.3), mixture version.

    s here is the largest exponent: levels p^s < ... < p < p^0 = 1.
    """
    s = 2 ** bits - 2
    if s <= 0:
        return jnp.zeros_like(p)
    # term for the lowest bin [0, p^s]: variance (p^{2s} - ... ) in the
    # paper's symmetric form; on [0,1] with level 0 present the lowest bin
    # is (p^s - r) r, whose p-derivative is s p^{s-1} * m1 on [0, p^s].
    # We differentiate Psi = sum_j int (l_{j+1}-r)(r-l_j) dF directly:
    #   d/dp [(p^{j}] = j p^{j-1}; bins are [p^{j+1}, p^j] for j=0..s-1
    #   plus [0, p^s].
    j = jnp.arange(0, s, dtype=p.dtype)  # j = 0..s-1
    a = p ** (j + 1)  # lower edge
    c = p ** j        # upper edge
    m0 = partial_moment0(stats, a, c)
    m1 = partial_moment1(stats, a, c)
    # d/dp int_a^c (c - r)(r - a) dF(r)
    #   = c'(p) * int (r - a) dF + a'(p) * int -(c - r) dF
    #   (Leibniz boundary terms vanish since the integrand is 0 at r=a,c)
    cprime = j * p ** jnp.maximum(j - 1, 0) * jnp.where(j == 0, 0.0, 1.0)
    aprime = (j + 1) * p ** j
    dbin = cprime * (m1 - a * m0) + aprime * (m1 - c * m0)
    # lowest bin [0, p^s]: integrand (p^s - r) * (r - 0)
    m1_low = partial_moment1(stats, jnp.zeros_like(p), p ** s)
    dlow = s * p ** (s - 1) * m1_low
    return jnp.sum(dbin) + dlow


@functools.partial(jax.jit, static_argnames=("bits", "steps"))
def amq_update(
    p: jnp.ndarray,
    stats: TruncNormStats,
    *,
    bits: int,
    lr: float = 0.05,
    steps: int = 100,
) -> jnp.ndarray:
    """GD on the multiplier with backtracking-free clipped steps."""

    def body(_, p):
        g = amq_gradient(p, stats, bits)
        p_new = p - lr * g
        return jnp.clip(p_new, 0.02, 0.98)

    return jax.lax.fori_loop(0, steps, body, jnp.asarray(p))

"""GradientCodec: the composable ENCODE -> pack -> wire layer.

Every byte that travels during quantized synchronization — the
``all_gather`` / ``two_phase`` collectives in ``dist.sync``, the FSDP
backward reduce-scatter in ``dist.fsdp``, and all ``repro.sim``
topologies — is produced and consumed here.  A codec owns three things:

``plan(d)``      The static wire layout of a ``d``-coordinate gradient:
                 padded bucket count, per-segment packed-word counts,
                 per-bucket wire widths, and the exact bits/coordinate
                 accounting.  Plans are hashable ``NamedTuple``s so
                 layouts are computed once per (shape, codec).

``encode``       (nb, bucket_size) values + levels + PRNG key ->
                 ``WirePayload``: a pytree of dense uint32 words (packed
                 level symbols) + uint32 norm words.  Transports move
                 payloads generically (``jax.tree.map(transport.f, p)``).

``decode``       The inverse: one fused pass over M gathered payload
                 streams -> (M, n) values.

Three codecs ship here (plus ``repro.compress.SparseCodec``):

``UniformCodec``     one global (bits, bucket_size) — the paper's wire
    format, bit-for-bit identical to the pre-codec implementation
    (pinned by ``tests/test_codec_goldens.py``).

``EntropyCodec``     the uniform symbol stream entropy-coded per bucket
    with a static canonical-Huffman table fit to the closed-form level
    occupancies (Thm 3's achievable cost, realized as bytes).  Payload
    arrays stay worst-case shape-static; the *measured* volume is read
    off per-bucket length headers (``WirePlan.variable``).

``MixedWidthCodec``  per-bucket wire widths inside one tensor.  The
    static width assignment comes from ``assign_mixed_widths``: given
    per-bucket truncated-normal statistics (the same ``TruncNormStats``
    the adaptive schemes fit), buckets with larger norm·sigma — where
    rounding noise costs the most — get more levels, under a mean
    bits/coordinate budget (cf. NUQSGD / DQ-SGD: *where* the bits go
    matters as much as how many).  The payload is ragged across width
    groups but statically planned, so it rides the same gather /
    all-to-all transports as the uniform payload.

Sharded plans (``shards=M``) describe payloads split per destination
worker (two_phase phase 1, the FSDP reduce-scatter): segment ``s`` of
every worker's payload holds buckets ``[s*shard_nb, (s+1)*shard_nb)``.
Mixed-width segments may differ in true word count; all are padded to
the static max so collectives see rectangular arrays.  Decoding the
(traced) own-shard segment inside SPMD code dispatches over the static
per-shard layouts with ``lax.switch``.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import packing
from .levels import num_levels as _num_levels_for_bits
from .quantize import pad_to_buckets
from .stats import TruncNormStats, expected_variance


class WirePayload(NamedTuple):
    """What actually travels: packed level symbols + packed bucket norms.

    Leaves are uint32.  Unsharded payloads are 1-D (``(code_words,)`` /
    ``(norm_words,)``); sharded payloads carry a leading segment axis
    ``(shards, ...)``; gathered payloads a leading stream axis.
    """

    words: jnp.ndarray
    norm_words: jnp.ndarray


class WirePlan(NamedTuple):
    """Static layout of one tensor's wire payload (hashable)."""

    d: int                 # original (unpadded) coordinate count
    bucket_size: int
    nb: int                # padded bucket count (tile/shard aligned)
    shards: int            # payload segments (1 = whole tensor)
    code_words: int        # uint32 words per segment (max over segments)
    norm_words: int        # norm words per segment
    widths: tuple | None   # per-bucket scheme bits (len nb); None=uniform
    bits_per_coord: float  # shipped wire bits (codes+norms) per coord
    # variable-volume accounting mode (the entropy-coded payload
    # family): the payload ARRAYS are still the static worst-case
    # capacity above (shape-static under jit/shard_map), but the bytes
    # that actually need to travel are data-dependent — read them off
    # the payload with ``codec.measured_bits_per_coord``.  For
    # ``variable=False`` codecs measured == planned by construction.
    variable: bool = False
    # wire-integrity mode: the payload carries one checksum word per
    # bucket (``packing.bucket_checksums`` over the bucket's symbols +
    # norm bit pattern, laid at the head of each segment's word
    # stream), and ``codec.decode_checked`` returns a per-stream
    # per-bucket validity mask next to the values.  Off by default —
    # the integrity-off layout is byte-identical to the pre-fault wire
    # (pinned by the codec goldens).
    integrity: bool = False

    @property
    def n(self) -> int:
        return self.nb * self.bucket_size

    @property
    def shard_nb(self) -> int:
        return self.nb // self.shards

    @property
    def shard_n(self) -> int:
        return self.shard_nb * self.bucket_size

    @property
    def payload_bytes(self) -> float:
        """Bytes of ONE (padded) segment payload."""
        return 4.0 * (self.code_words + self.norm_words)


def resample_levels(levels: jnp.ndarray, num_out: int) -> jnp.ndarray:
    """Re-grid a level vector to ``num_out`` points on [0, 1].

    Linear interpolation in level-index space: the resampled grid keeps
    the endpoints (0, 1) and the *shape* of the adaptive grid, so a
    mixed-width codec inherits ALQ/AMQ adaptation at every width from
    the single base level vector carried in ``SchemeState``.
    """
    L = levels.shape[0]
    if num_out == L:
        return levels
    pos = jnp.linspace(0.0, float(L - 1), num_out, dtype=jnp.float32)
    return jnp.interp(pos, jnp.arange(L, dtype=jnp.float32),
                      levels.astype(jnp.float32))


def _align_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GradientCodec:
    """Base codec: bucketing + norm side-channel; subclasses own the
    symbol layout.  All layout decisions are static (trace-time)."""

    bucket_size: int = 8192
    norm_type: str = "l2"
    norm_dtype: str = "float32"
    # opt-in wire integrity: lay a per-bucket checksum word into the
    # payload and expose ``decode_checked`` (values + validity mask).
    # Supported by the dense single-alphabet codecs (uniform, entropy);
    # mixed-width / sparse payload families raise.
    integrity: bool = False

    @property
    def chunkable(self) -> bool:
        """Whether payloads may be re-planned over arbitrary bucket
        sub-ranges (the FSDP round-overlap chunking).  Mixed-width
        layouts are planned per whole shard and are not."""
        return True

    @property
    def _norm_bits_per_coord(self) -> float:
        return (32.0 if self.norm_dtype == "float32" else
                16.0) / self.bucket_size

    @property
    def nominal_bits_per_coord(self) -> float:
        """Asymptotic wire bits per coordinate (symbols + norm
        side-channel), ignoring word-alignment slop — for cost reporting
        where no concrete plan exists yet."""
        raise NotImplementedError

    # -- planning ---------------------------------------------------------

    def plan(self, d: int, *, shards: int = 1,
             tile: int | None = None) -> WirePlan:
        """Layout for a ``d``-coordinate tensor split into ``shards``
        segments; bucket count is padded to ``shards * tile``."""
        if tile is None:
            from repro.kernels.quantize import DEFAULT_BUCKET_TILE
            tile = DEFAULT_BUCKET_TILE
        nb = _align_up(-(-d // self.bucket_size), shards * tile)
        return self.plan_buckets(nb, shards=shards, d=d)

    def plan_buckets(self, nb: int, *, shards: int = 1,
                     d: int | None = None) -> WirePlan:
        """Layout for an exact (already aligned) bucket count."""
        raise NotImplementedError

    # -- value <-> wire ---------------------------------------------------

    def bucketize(self, flat: jnp.ndarray, plan: WirePlan) -> jnp.ndarray:
        """(d,) -> (nb, bucket_size) zero-padded to the plan's layout.

        Zero buckets are exact fixed points of ENCODE/DECODE (norm 0,
        code 0), so padding never leaks into aggregates.
        """
        vb = pad_to_buckets(flat.reshape(-1), self.bucket_size)
        nb = vb.shape[0]
        if plan.nb != nb:
            vb = jnp.concatenate(
                [vb, jnp.zeros((plan.nb - nb, self.bucket_size), vb.dtype)])
        return vb

    def encode(self, vb: jnp.ndarray, levels: jnp.ndarray, key: jax.Array,
               plan: WirePlan, *, use_pallas: bool = True) -> WirePayload:
        """(nb, bucket_size) -> packed payload (segmented per the plan)."""
        raise NotImplementedError

    def decode(self, payload: WirePayload, levels: jnp.ndarray,
               plan: WirePlan, *, shard=None,
               use_pallas: bool = True) -> jnp.ndarray:
        """Packed payload stream(s) -> values.

        1-D payload leaves decode to ``(segment_n,)``; leaves with a
        leading stream axis decode to ``(M, segment_n)`` in one fused
        pass.  For sharded plans, ``shard`` names the segment the
        streams carry: a static int, a traced index (SPMD rank —
        dispatched via ``lax.switch`` over the static per-shard
        layouts), or ``None`` meaning stream ``i`` carries segment ``i``
        (decoding one's own sharded payload).
        """
        raise NotImplementedError

    def decode_checked(self, payload: WirePayload, levels: jnp.ndarray,
                       plan: WirePlan, *, shard=None,
                       use_pallas: bool = True):
        """``decode`` plus a per-stream per-bucket validity verdict.

        Returns ``(vals, valid)`` where ``valid`` is a bool array of
        shape ``(snb,)`` for a 1-D payload / ``(M, snb)`` for gathered
        streams: ``True`` iff the bucket's wire words passed every
        integrity check (checksum word, entropy header sanity).  For
        ``plan.integrity=False`` codecs everything is vacuously valid
        — this default keeps codecs without an integrity layout usable
        behind the same call.
        """
        vals = self.decode(payload, levels, plan, shard=shard,
                           use_pallas=use_pallas)
        if payload.words.ndim == 1:
            shape: tuple = (plan.shard_nb,)
        else:
            shape = (payload.words.shape[0], plan.shard_nb)
        return vals, jnp.ones(shape, bool)

    def requantize(self, vb: jnp.ndarray, levels: jnp.ndarray,
                   key: jax.Array, plan: WirePlan, *, chunk: int = 0,
                   use_pallas: bool = True) -> jnp.ndarray:
        """Value-space wire round trip Q(vb) of one plan segment —
        what a per-hop re-quantizing topology (sim ring) injects.
        ``vb`` holds segment ``chunk``'s buckets; norms take the packed
        wire round trip so values match the byte accounting.
        """
        raise NotImplementedError

    # -- accounting -------------------------------------------------------

    def measured_bits_per_coord(self, payload: WirePayload,
                                plan: WirePlan) -> jnp.ndarray:
        """Wire bits per original coordinate that ``payload`` actually
        needs to ship — the whole tensor's cost when ``payload`` is this
        worker's own (1-D or ``(shards, ...)``-sharded) encode.

        Fixed-layout codecs ship exactly the plan (``WirePlan
        .bits_per_coord``); variable-volume codecs
        (``plan.variable=True``) override this to read the per-bucket
        coded lengths out of the payload headers, so the number is a
        traced, data-dependent float32.
        """
        del payload
        return jnp.float32(plan.bits_per_coord)


def _unpack_norm_rows(nwords: jnp.ndarray, nb: int,
                      norm_dtype: str) -> jnp.ndarray:
    return jax.vmap(
        lambda w: packing.unpack_norms(w, nb, norm_dtype))(nwords)


# ---------------------------------------------------------------------------
# uniform codec: one global width (the paper's wire format)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UniformCodec(GradientCodec):
    """One (num_levels, bucket_size) for every bucket.

    This is the production codec: encode is one fused quantize kernel,
    the symbol stream is one fixed-width pack per segment, and decode is
    one fused dequantize over all gathered streams.  Bit-identical to
    the pre-codec ``dist.sync`` / ``dist.fsdp`` wire paths.
    """

    num_levels: int = 8

    @property
    def nominal_bits_per_coord(self) -> float:
        return (packing.wire_bits_for(self.num_levels)
                + self._norm_bits_per_coord)

    def plan_buckets(self, nb: int, *, shards: int = 1,
                     d: int | None = None) -> WirePlan:
        if nb % shards:
            raise ValueError(f"nb={nb} not divisible by shards={shards}")
        if d is None:
            d = nb * self.bucket_size
        wb = packing.wire_bits_for(self.num_levels)
        snb = nb // shards
        cw = packing.packed_words(snb * self.bucket_size, wb)
        if self.integrity:
            cw += snb                     # per-bucket checksum words
        nw = packing.norm_words(snb, self.norm_dtype)
        return WirePlan(d=d, bucket_size=self.bucket_size, nb=nb,
                        shards=shards, code_words=cw, norm_words=nw,
                        widths=None,
                        bits_per_coord=32.0 * shards * (cw + nw) / d,
                        integrity=self.integrity)

    def encode(self, vb, levels, key, plan, *, use_pallas=True):
        from repro.kernels import ops
        u = jax.random.uniform(key, vb.shape, jnp.float32)
        codes, norms = ops.quantize_op(vb, u, levels,
                                       norm_type=self.norm_type,
                                       use_pallas=use_pallas)
        L = levels.shape[0]
        snb = plan.shard_nb
        if self.integrity:
            csum = packing.bucket_checksums(
                packing.bias_codes(codes, L),
                packing.norm_bit_patterns(norms, self.norm_dtype))

        def seg_words(j):
            w = packing.pack_signed(
                jax.lax.slice_in_dim(codes, j * snb, (j + 1) * snb), L)
            if self.integrity:
                w = jnp.concatenate(
                    [jax.lax.slice_in_dim(csum, j * snb, (j + 1) * snb),
                     w])
            return w

        if plan.shards == 1:
            return WirePayload(
                words=seg_words(0),
                norm_words=packing.pack_norms(norms, self.norm_dtype))
        words = jnp.stack([seg_words(j) for j in range(plan.shards)])
        nwords = jax.vmap(
            lambda x: packing.pack_norms(x, self.norm_dtype))(
                norms.reshape(plan.shards, snb))
        return WirePayload(words=words, norm_words=nwords)

    def _decode_uniform(self, payload, levels, plan, use_pallas,
                        want_valid):
        from repro.kernels import ops
        words, nwords = payload
        single = words.ndim == 1
        if single:
            words, nwords = words[None], nwords[None]
        snb = plan.shard_nb
        n = plan.shard_n
        stored = None
        if plan.integrity:
            stored = jax.lax.slice_in_dim(words, 0, snb, axis=1)
            words = jax.lax.slice_in_dim(words, snb, words.shape[1],
                                         axis=1)
        norms = _unpack_norm_rows(nwords, snb, self.norm_dtype)
        L = levels.shape[0]
        M = norms.shape[0]
        wb = packing.wire_bits_for(L)
        usym = jax.vmap(lambda w: packing.unpack(w, n, wb))(words)
        sym = packing.unbias_codes(usym, L)
        vals = ops.dequantize_op(
            sym.reshape(M * snb, self.bucket_size), norms.reshape(-1),
            levels, use_pallas=use_pallas)
        vals = vals.reshape(M, n)
        valid = None
        if want_valid:
            if stored is None:
                valid = jnp.ones((M, snb), bool)
            else:
                calc = jax.vmap(packing.bucket_checksums)(
                    usym.reshape(M, snb, self.bucket_size),
                    jax.vmap(lambda x: packing.norm_bit_patterns(
                        x, self.norm_dtype))(norms))
                valid = calc == stored
        if single:
            vals = vals[0]
            valid = None if valid is None else valid[0]
        return vals, valid

    def decode(self, payload, levels, plan, *, shard=None, use_pallas=True):
        vals, _ = self._decode_uniform(payload, levels, plan, use_pallas,
                                       want_valid=False)
        return vals

    def decode_checked(self, payload, levels, plan, *, shard=None,
                       use_pallas=True):
        return self._decode_uniform(payload, levels, plan, use_pallas,
                                    want_valid=True)

    def requantize(self, vb, levels, key, plan, *, chunk=0,
                   use_pallas=True):
        from repro.kernels import ops
        u = jax.random.uniform(key, vb.shape, jnp.float32)
        codes, norms = ops.quantize_op(vb, u, levels,
                                       norm_type=self.norm_type,
                                       use_pallas=use_pallas)
        wn = packing.unpack_norms(
            packing.pack_norms(norms, self.norm_dtype), norms.shape[0],
            self.norm_dtype)
        return ops.dequantize_op(codes, wn, levels, use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# entropy codec: the metered H(L) cost realized as actual coded bytes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EntropyCodec(UniformCodec):
    """Canonical-Huffman entropy coding of the uniform symbol stream.

    Since PR 3 the achievable entropy-coded cost of the adaptive grid is
    *metered* (``SchemeState.entropy_bits`` -> ``SyncMetrics
    .entropy_bits_per_coord``) while every wire word stays fixed-width.
    This codec closes that gap: the same quantize kernel and the same
    key schedule as ``UniformCodec`` (so decoded values are bit-exact
    with it — pinned in ``tests/test_entropy_codec.py``), but each
    bucket's symbol run travels as variable-length canonical-Huffman
    codewords (QSGD's Elias trick, upgraded to the closed-form level
    occupancies ``coding.level_probabilities`` the adaptive schemes
    already fit).

    Wire layout of one payload segment (``shard_nb`` buckets)::

        [ header: shard_nb words — bit 31 = fixed-width fallback flag,
                  bits 0..30 = coded bit length of the bucket          ]
        [ bucket 0 region: cap_words words (worst-case capacity)       ]
        ...
        [ bucket shard_nb-1 region: cap_words words                    ]
        norm side-channel: unchanged (packed bucket norms)

    ``cap_words = packed_words(bucket_size, wire_bits)`` is the
    fixed-width budget of one bucket, so the payload arrays are
    shape-static (jit/shard_map/vmap-safe) and every segment has the
    SAME static layout — sharded decode needs no ``lax.switch``, and
    the FSDP chunked reduce-scatter re-plans freely (``chunkable``).
    A bucket whose coded run would overflow its capacity falls back to
    fixed-width packing in place (one flag bit in its header), so the
    codec never ships MORE than ``capacity + one header word`` per
    bucket and decode never reads past the region.

    The *measured* wire volume — what ``measured_bits_per_coord`` reads
    back out of the headers and what ``dist.sync`` / ``repro.sim`` bill
    — is ``ceil(coded_bits/32)`` words per bucket, not the capacity:
    the number that converges onto the metered
    ``entropy_bits_per_coord`` curve as the grid adapts.

    ``huff_lengths`` / ``huff_codes`` are the static per-symbol table
    over the ``2L - 1`` signed-symbol alphabet (``coding
    .entropy_table``), LSB-first wire codewords.  Like a mixed-width
    pattern, the table is static trace-time configuration: it is built
    host-side from ``level_probabilities`` at level-update time (the
    sim's ``entropy_coded`` scenario re-fits it at every milestone) and
    any staleness costs only bytes, never correctness — decodability
    depends on the prefix code alone, not on the data distribution.
    """

    huff_lengths: tuple = ()
    huff_codes: tuple = ()

    def __post_init__(self):
        from .coding import MAX_CODE_BITS
        S = 2 * self.num_levels - 1
        if len(self.huff_lengths) != S or len(self.huff_codes) != S:
            raise ValueError(
                f"entropy table must cover the {S}-symbol signed "
                f"alphabet, got {len(self.huff_lengths)} lengths / "
                f"{len(self.huff_codes)} codes (build one with "
                "coding.entropy_table or entropy_wrap)")
        bad = [l for l in self.huff_lengths
               if not 1 <= int(l) <= MAX_CODE_BITS]
        if bad:
            raise ValueError(
                f"codeword lengths must be in [1, {MAX_CODE_BITS}], "
                f"got {bad}")

    # -- static layout ----------------------------------------------------

    @property
    def _wire_bits(self) -> int:
        return packing.wire_bits_for(self.num_levels)

    @property
    def cap_words(self) -> int:
        """Worst-case capacity of one bucket's coded region (== its
        fixed-width word count, so the fallback always fits)."""
        return packing.packed_words(self.bucket_size, self._wire_bits)

    @property
    def nominal_bits_per_coord(self) -> float:
        # worst-case (capacity) accounting: header + fixed-width budget
        return (32.0 * (1 + self.cap_words) / self.bucket_size
                + self._norm_bits_per_coord)

    def plan_buckets(self, nb: int, *, shards: int = 1,
                     d: int | None = None) -> WirePlan:
        if nb % shards:
            raise ValueError(f"nb={nb} not divisible by shards={shards}")
        if d is None:
            d = nb * self.bucket_size
        snb = nb // shards
        cw = snb * (1 + self.cap_words)
        if self.integrity:
            cw += snb                     # per-bucket checksum words
        nw = packing.norm_words(snb, self.norm_dtype)
        return WirePlan(d=d, bucket_size=self.bucket_size, nb=nb,
                        shards=shards, code_words=cw, norm_words=nw,
                        widths=None,
                        bits_per_coord=32.0 * shards * (cw + nw) / d,
                        variable=True, integrity=self.integrity)

    # -- table as device constants ---------------------------------------

    def _table(self):
        lens = jnp.asarray(self.huff_lengths, jnp.uint32)
        codes = jnp.asarray(self.huff_codes, jnp.uint32)
        masks = jnp.where(lens >= 32, jnp.uint32(0xFFFFFFFF),
                          (jnp.uint32(1) << lens) - jnp.uint32(1))
        return lens, codes, masks

    # -- value <-> wire ---------------------------------------------------

    def encode(self, vb, levels, key, plan, *, use_pallas=True):
        from repro.kernels import ops
        u = jax.random.uniform(key, vb.shape, jnp.float32)
        codes, norms = ops.quantize_op(vb, u, levels,
                                       norm_type=self.norm_type,
                                       use_pallas=use_pallas)
        L = levels.shape[0]
        sym = packing.bias_codes(codes, L)              # (nb, bs)
        wb = self._wire_bits
        cap = self.cap_words
        bs = self.bucket_size
        len_t, code_t, _ = self._table()

        lens = len_t[sym]                               # (nb, bs)
        tot = jnp.sum(lens, axis=1)                     # coded bits
        fallback = tot > jnp.uint32(32 * cap)

        # fallback region: the plain fixed-width pack of each bucket
        fixed = jax.vmap(lambda c: packing.pack(c, wb))(sym)  # (nb, cap)

        # huffman region: scatter codeword fragments at cumulative bit
        # offsets (same two-scatter low/spill scheme as packing.pack;
        # codewords are <= 32 bits so each spills into at most one
        # following word).  Overflowing buckets scatter out of range
        # with mode='drop' — their region is replaced by `fixed` anyway.
        pos = jnp.cumsum(lens, axis=1) - lens
        cw_sym = code_t[sym]
        widx = (pos >> 5).astype(jnp.int32)
        off = pos & jnp.uint32(31)
        lo = (cw_sym << off).astype(jnp.uint32)
        spill = jnp.where(off > 0, jnp.uint32(32) - off, jnp.uint32(31))
        hi = jnp.where(off > 0, cw_sym >> spill, jnp.uint32(0))

        def pack_var(w_idx, lo_b, hi_b):
            out = jnp.zeros((cap + 1,), jnp.uint32)
            out = out.at[w_idx].add(lo_b, mode="drop")
            out = out.at[w_idx + 1].add(hi_b, mode="drop")
            return out[:cap]

        var = jax.vmap(pack_var)(widx, lo, hi)          # (nb, cap)

        used = jnp.where(fallback, jnp.uint32(bs * wb), tot)
        header = used | (fallback.astype(jnp.uint32) << 31)
        region = jnp.where(fallback[:, None], fixed, var)

        snb = plan.shard_nb
        if self.integrity:
            csum = packing.bucket_checksums(
                sym, packing.norm_bit_patterns(norms, self.norm_dtype))

        def seg(s):
            h = jax.lax.slice_in_dim(header, s * snb, (s + 1) * snb)
            r = jax.lax.slice_in_dim(region, s * snb,
                                     (s + 1) * snb).reshape(-1)
            parts = [h, r]
            if self.integrity:
                parts.insert(0, jax.lax.slice_in_dim(
                    csum, s * snb, (s + 1) * snb))
            return jnp.concatenate(parts)

        if plan.shards == 1:
            return WirePayload(
                words=seg(0),
                norm_words=packing.pack_norms(norms, self.norm_dtype))
        words = jnp.stack([seg(s) for s in range(plan.shards)])
        nwords = jax.vmap(
            lambda x: packing.pack_norms(x, self.norm_dtype))(
                norms.reshape(plan.shards, snb))
        return WirePayload(words=words, norm_words=nwords)

    def _decode_entropy(self, payload, levels, plan, use_pallas,
                        want_valid):
        from repro.kernels import ops
        words, nwords = payload
        single = words.ndim == 1
        if single:
            words, nwords = words[None], nwords[None]
        snb = plan.shard_nb
        bs = self.bucket_size
        cap = self.cap_words
        wb = self._wire_bits
        L = levels.shape[0]
        M = words.shape[0]
        norms = _unpack_norm_rows(nwords, snb, self.norm_dtype)
        stored = None
        off = 0
        if plan.integrity:
            stored = jax.lax.slice_in_dim(words, 0, snb, axis=1)
            off = snb
        headers = jax.lax.slice_in_dim(words, off, off + snb, axis=1)
        regions = jax.lax.slice_in_dim(
            words, off + snb, off + snb * (1 + cap),
            axis=1).reshape(M, snb, cap)
        fallback = (headers >> 31) > 0                  # (M, snb)

        # fixed-width path (vectorized; selected per bucket by the flag)
        sym_fixed = jax.vmap(jax.vmap(
            lambda r: packing.unpack(r, bs, wb)))(regions)

        # huffman path: sequential prefix decode, one lax.scan of
        # bucket_size symbols per bucket.  At each bit position the next
        # <=32 bits are matched against the whole codeword table at
        # once; prefix-freeness guarantees a unique hit.
        len_t, code_t, mask_t = self._table()

        def dec_bucket(region):
            w = jnp.concatenate([region, jnp.zeros((1,), jnp.uint32)])

            def body(pos, _):
                wi = (pos >> 5).astype(jnp.int32)
                o = pos & jnp.uint32(31)
                sp = jnp.where(o > 0, jnp.uint32(32) - o, jnp.uint32(31))
                u = (w[wi] >> o) | jnp.where(
                    o > 0, w[jnp.minimum(wi + 1, cap)] << sp,
                    jnp.uint32(0))
                s = jnp.argmax((u & mask_t) == code_t)
                return pos + len_t[s], s.astype(jnp.int32)

            _, syms = jax.lax.scan(body, jnp.uint32(0), None, length=bs)
            return syms

        sym_var = jax.vmap(jax.vmap(dec_bucket))(regions)
        sym = jnp.where(fallback[..., None], sym_fixed, sym_var)
        vals = ops.dequantize_op(
            packing.unbias_codes(sym.reshape(M * snb, bs), L),
            norms.reshape(-1), levels, use_pallas=use_pallas)
        vals = vals.reshape(M, snb * bs)
        valid = None
        if want_valid:
            if stored is None:
                valid = jnp.ones((M, snb), bool)
            else:
                # checksum over the decoded symbols + norm bits ...
                calc = jax.vmap(packing.bucket_checksums)(
                    sym.astype(jnp.uint32),
                    jax.vmap(lambda x: packing.norm_bit_patterns(
                        x, self.norm_dtype))(norms))
                valid = calc == stored
                # ... AND header sanity: a fallback bucket's length is
                # exactly the fixed-width run; a coded bucket's length
                # fits its capacity.  (A corrupt header word can only
                # inflate the billed volume or misroute the decode —
                # both are caught here.)
                used = headers & jnp.uint32(0x7FFFFFFF)
                sane = jnp.where(fallback,
                                 used == jnp.uint32(bs * wb),
                                 used <= jnp.uint32(32 * cap))
                valid = valid & sane
        if single:
            vals = vals[0]
            valid = None if valid is None else valid[0]
        return vals, valid

    def decode(self, payload, levels, plan, *, shard=None,
               use_pallas=True):
        # every segment has the same static layout, so `shard` (static
        # or traced) never changes the decode — accepted for protocol
        # compatibility, like SparseCodec
        vals, _ = self._decode_entropy(payload, levels, plan, use_pallas,
                                       want_valid=False)
        return vals

    def decode_checked(self, payload, levels, plan, *, shard=None,
                       use_pallas=True):
        return self._decode_entropy(payload, levels, plan, use_pallas,
                                    want_valid=True)

    # requantize: inherited from UniformCodec — the value-space round
    # trip is identical (entropy coding is lossless on the symbols).

    def measured_bits_per_coord(self, payload, plan):
        words = payload.words
        if words.ndim == 1:
            words = words[None]
        snb = plan.shard_nb
        off = snb if plan.integrity else 0
        headers = jax.lax.slice_in_dim(words, off, off + snb, axis=1)
        used = headers & jnp.uint32(0x7FFFFFFF)
        # a corrupt header cannot bill more than the bucket's capacity
        used = jnp.minimum(used, jnp.uint32(32 * self.cap_words))
        coded = jnp.sum((used + jnp.uint32(31)) >> 5)   # ceil words
        overhead = snb + off                            # header (+ csum)
        total = (coded.astype(jnp.float32)
                 + words.shape[0] * (overhead + plan.norm_words))
        return 32.0 * total / plan.d


def entropy_wrap(base: GradientCodec, level_probs=None) -> EntropyCodec:
    """Wrap a base codec's wire in the canonical-Huffman entropy coder.

    ``level_probs`` are magnitude-level occupancies
    (``coding.level_probabilities`` of the current grid under the
    fitted stats); ``None`` installs the cold-start table (uniform
    joint occupancies — decodable from step 0, measured ~ fixed width
    until a real fit arrives).  Only the uniform symbol stream is
    entropy-codable today: mixed-width / sparse payload families raise.
    """
    from .coding import entropy_table
    if type(base) not in (UniformCodec, EntropyCodec):
        raise ValueError(
            "entropy coding wraps the uniform symbol stream; got "
            f"{type(base).__name__} (mixed-width and sparse payloads "
            "have no single-alphabet symbol run to code)")
    lengths, codes = entropy_table(
        None if level_probs is None else np.asarray(level_probs),
        base.num_levels)
    return EntropyCodec(bucket_size=base.bucket_size,
                        norm_type=base.norm_type,
                        norm_dtype=base.norm_dtype,
                        integrity=base.integrity,
                        num_levels=base.num_levels,
                        huff_lengths=lengths, huff_codes=codes)


def entropy_codec_for_scheme(scheme) -> EntropyCodec:
    """The scheme's entropy codec with the *gaussian-prior* table.

    Before any statistics exist, normalized bucket magnitudes of an
    i.i.d.-gaussian gradient are well modelled in closed form:
    ``E r ~ 1/sqrt(bucket_size)`` under L2 normalization, ``~
    1/sqrt(2 ln bucket_size)`` under L-inf.  Fitting the table to that
    one-component prior (instead of uniform occupancies) makes
    ``codec='entropy'`` compress from step 0 on near-gaussian
    gradients; a mismatch costs only the per-bucket fallback.  The sim
    / probe paths replace this with a table fit to real occupancies.
    """
    from .coding import level_probabilities
    from .quantize import NORM_LINF
    if scheme.norm_type == NORM_LINF:
        scale = 1.0 / np.sqrt(2.0 * np.log(max(scheme.bucket_size, 2)))
    else:
        scale = 1.0 / np.sqrt(scheme.bucket_size)
    prior = TruncNormStats(
        mu=jnp.asarray([scale], jnp.float32),
        sigma=jnp.asarray([scale], jnp.float32),
        gamma=jnp.asarray([1.0], jnp.float32))
    probs = level_probabilities(
        jnp.asarray(scheme.init_levels(), jnp.float32), prior)
    return entropy_wrap(codec_for_scheme(scheme), np.asarray(probs))


def entropy_codec_from_gradient(flat, scheme, levels=None, *,
                                use_pallas: bool = False) -> EntropyCodec:
    """The probe-step protocol for the entropy wire: one gradient -> a
    fitted canonical-Huffman table.

    One fused ``bucket_stats`` sweep, the same ``stats_from_moments``
    reduction the level updates consume, then ``level_probabilities``
    of the (current) grid -> ``entropy_wrap``.  Shared by the
    simulator's ``entropy_coded`` scenario (re-run at every level-update
    milestone) and ``benchmarks/bench_entropy.py``.
    """
    from repro.kernels import ops
    from .coding import level_probabilities
    from .stats import stats_from_moments
    flat = jnp.asarray(flat).reshape(-1)
    base = codec_for_scheme(scheme)
    vb = base.bucketize(flat, base.plan(flat.shape[0]))
    norms, mu, var = ops.bucket_stats_op(vb, norm_type=scheme.norm_type,
                                         use_pallas=use_pallas)
    nb_valid = max(flat.shape[0] // scheme.bucket_size, 1)
    stats = stats_from_moments(
        mu[:nb_valid], var[:nb_valid], norms[:nb_valid],
        weighted=scheme.weighted_stats,
        max_components=scheme.max_stat_components)
    if levels is None:
        levels = scheme.init_levels()
    probs = level_probabilities(jnp.asarray(levels, jnp.float32), stats)
    return entropy_wrap(base, np.asarray(probs))


# ---------------------------------------------------------------------------
# mixed-width codec: per-bucket widths, one tensor, one wire
# ---------------------------------------------------------------------------

class _Group(NamedTuple):
    """One width group inside one segment (all static)."""

    bits: int            # scheme bits of the group's grid
    nlev: int            # 2**bits levels
    local_idx: tuple     # bucket indices local to the segment
    word_off: int        # offset into the segment's word stream
    word_cnt: int


@functools.lru_cache(maxsize=256)
def _segment_layouts(widths: tuple, shards: int,
                     bucket_size: int) -> tuple:
    """Per-segment width-group layouts: ``layouts[s]`` is a tuple of
    ``_Group`` covering segment ``s``'s buckets, words concatenated in
    ascending-width order, each group word-aligned."""
    nb = len(widths)
    snb = nb // shards
    layouts = []
    for s in range(shards):
        seg = np.asarray(widths[s * snb:(s + 1) * snb])
        groups, off = [], 0
        for b in sorted(set(seg.tolist())):
            loc = tuple(np.nonzero(seg == b)[0].tolist())
            nlev = _num_levels_for_bits(b)
            cnt = packing.packed_words(len(loc) * bucket_size,
                                      packing.wire_bits_for(nlev))
            groups.append(_Group(bits=b, nlev=nlev, local_idx=loc,
                                 word_off=off, word_cnt=cnt))
            off += cnt
        layouts.append(tuple(groups))
    return tuple(layouts)


@dataclasses.dataclass(frozen=True)
class MixedWidthCodec(GradientCodec):
    """Per-bucket wire widths inside one tensor.

    ``widths`` is a static per-bucket scheme-bits pattern, tiled
    cyclically over the plan's bucket count (a full ``nb``-length
    assignment from ``assign_mixed_widths`` is the common case).  Each
    width group encodes on ``resample_levels(levels, 2**bits)`` — the
    adaptive base grid re-sampled to the group's resolution — so level
    adaptation still happens once, on the base grid.

    The symbol stream of a segment is the concatenation of its width
    groups' fixed-width packs (ascending width, each word-aligned);
    segments are zero-padded to the plan's ``code_words`` so sharded
    collectives stay rectangular.
    """

    widths: tuple = ()

    def __post_init__(self):
        if self.integrity:
            raise ValueError(
                "MixedWidthCodec has no integrity layout (the ragged "
                "width-group stream carries no per-bucket checksum "
                "slot); use the uniform or entropy codec for "
                "fault-tolerant wires")
        if not self.widths:
            raise ValueError("MixedWidthCodec needs a non-empty widths "
                             "pattern (per-bucket scheme bits)")
        bad = [b for b in self.widths if not 1 <= int(b) <= 8]
        if bad:
            raise ValueError(f"widths must be in [1, 8], got {bad}")

    @property
    def chunkable(self) -> bool:
        return False

    @property
    def mean_scheme_bits(self) -> float:
        return float(np.mean(self.widths))

    @property
    def nominal_bits_per_coord(self) -> float:
        wire = np.mean([packing.wire_bits_for(_num_levels_for_bits(int(b)))
                        for b in self.widths])
        return float(wire) + self._norm_bits_per_coord

    def plan_buckets(self, nb: int, *, shards: int = 1,
                     d: int | None = None) -> WirePlan:
        if nb % shards:
            raise ValueError(f"nb={nb} not divisible by shards={shards}")
        if d is None:
            d = nb * self.bucket_size
        widths = tuple(int(b) for b in np.resize(
            np.asarray(self.widths, np.int64), nb))
        layouts = _segment_layouts(widths, shards, self.bucket_size)
        cw = max(sum(g.word_cnt for g in seg) for seg in layouts)
        nw = packing.norm_words(nb // shards, self.norm_dtype)
        return WirePlan(d=d, bucket_size=self.bucket_size, nb=nb,
                        shards=shards, code_words=cw, norm_words=nw,
                        widths=widths,
                        bits_per_coord=32.0 * shards * (cw + nw) / d)

    # -- helpers ----------------------------------------------------------

    def _group_levels(self, levels: jnp.ndarray, nlev: int) -> jnp.ndarray:
        return resample_levels(levels, nlev)

    def _quantize_groups(self, vb, u, levels, plan, use_pallas):
        """Quantize each width group once, globally.

        Returns (codes by width {bits: (cnt, bs)}, row index into the
        width's code block for every absolute bucket, full-order norms).
        """
        from repro.kernels import ops
        widths = np.asarray(plan.widths)
        nb = plan.nb
        codes_by, row_of = {}, np.zeros(nb, np.int64)
        norms_full = jnp.zeros((nb,), jnp.float32)
        for b in sorted(set(widths.tolist())):
            idx = np.nonzero(widths == b)[0]
            row_of[idx] = np.arange(len(idx))
            lv = self._group_levels(levels, _num_levels_for_bits(b))
            c, nrm = ops.quantize_op(vb[idx], u[idx], lv,
                                     norm_type=self.norm_type,
                                     use_pallas=use_pallas)
            codes_by[b] = c
            norms_full = norms_full.at[idx].set(nrm)
        return codes_by, row_of, norms_full

    def encode(self, vb, levels, key, plan, *, use_pallas=True):
        u = jax.random.uniform(key, vb.shape, jnp.float32)
        codes_by, row_of, norms = self._quantize_groups(
            vb, u, levels, plan, use_pallas)
        layouts = _segment_layouts(plan.widths, plan.shards,
                                   self.bucket_size)
        snb = plan.shard_nb
        rows = []
        for s, seg in enumerate(layouts):
            parts = []
            for g in seg:
                rows_g = row_of[np.asarray(g.local_idx) + s * snb]
                parts.append(packing.pack_signed(
                    codes_by[g.bits][rows_g], g.nlev))
            w = jnp.concatenate(parts) if parts else jnp.zeros(
                (0,), jnp.uint32)
            pad = plan.code_words - w.shape[0]
            if pad:
                w = jnp.concatenate([w, jnp.zeros((pad,), jnp.uint32)])
            rows.append(w)
        nrows = [packing.pack_norms(norms[s * snb:(s + 1) * snb],
                                    self.norm_dtype)
                 for s in range(plan.shards)]
        if plan.shards == 1:
            return WirePayload(words=rows[0], norm_words=nrows[0])
        return WirePayload(words=jnp.stack(rows),
                           norm_words=jnp.stack(nrows))

    def _decode_segment(self, words, norms, levels, seg, use_pallas):
        """(M, code_words) streams of ONE segment -> (M, shard_n)."""
        from repro.kernels import ops
        M = words.shape[0]
        bs = self.bucket_size
        snb = norms.shape[1]
        out = jnp.zeros((M, snb, bs), jnp.float32)
        for g in seg:
            cnt = len(g.local_idx)
            sl = jax.lax.slice_in_dim(words, g.word_off,
                                      g.word_off + g.word_cnt, axis=1)
            sym = jax.vmap(
                lambda w: packing.unpack_signed(w, cnt * bs, g.nlev))(sl)
            lv = self._group_levels(levels, g.nlev)
            loc = np.asarray(g.local_idx)
            vals = ops.dequantize_op(
                sym.reshape(M * cnt, bs), norms[:, loc].reshape(-1), lv,
                use_pallas=use_pallas)
            out = out.at[:, loc].set(vals.reshape(M, cnt, bs))
        return out.reshape(M, snb * bs)

    def decode(self, payload, levels, plan, *, shard=None,
               use_pallas=True):
        words, nwords = payload
        single = words.ndim == 1
        if single:
            words, nwords = words[None], nwords[None]
        norms = _unpack_norm_rows(nwords, plan.shard_nb, self.norm_dtype)
        layouts = _segment_layouts(plan.widths, plan.shards,
                                   self.bucket_size)
        if plan.shards == 1:
            vals = self._decode_segment(words, norms, levels, layouts[0],
                                        use_pallas)
            return vals[0] if single else vals
        if shard is None:
            # stream i carries segment i (own sharded payload)
            if words.shape[0] != plan.shards:
                raise ValueError(
                    f"diagonal decode needs {plan.shards} streams, got "
                    f"{words.shape[0]}")
            return jnp.stack([
                self._decode_segment(words[s][None], norms[s][None],
                                     levels, layouts[s], use_pallas)[0]
                for s in range(plan.shards)])
        if isinstance(shard, (int, np.integer)):
            return self._decode_segment(words, norms, levels,
                                        layouts[int(shard)], use_pallas)
        # traced segment index (SPMD rank): dispatch over static layouts
        return jax.lax.switch(
            jnp.asarray(shard, jnp.int32),
            [functools.partial(self._decode_segment, seg=seg,
                               use_pallas=use_pallas)
             for seg in layouts],
            words, norms, levels)

    def requantize(self, vb, levels, key, plan, *, chunk=0,
                   use_pallas=True):
        from repro.kernels import ops
        seg = _segment_layouts(plan.widths, plan.shards,
                               self.bucket_size)[int(chunk)]
        u = jax.random.uniform(key, vb.shape, jnp.float32)
        out = jnp.zeros_like(vb)
        for g in seg:
            loc = np.asarray(g.local_idx)
            lv = self._group_levels(levels, g.nlev)
            codes, nrm = ops.quantize_op(vb[loc], u[loc], lv,
                                         norm_type=self.norm_type,
                                         use_pallas=use_pallas)
            wn = packing.unpack_norms(
                packing.pack_norms(nrm, self.norm_dtype), nrm.shape[0],
                self.norm_dtype)
            out = out.at[loc].set(
                ops.dequantize_op(codes, wn, lv, use_pallas=use_pallas))
        return out


# ---------------------------------------------------------------------------
# width assignment: where should the bits go?
# ---------------------------------------------------------------------------

def assign_mixed_widths(
    mu, sigma, bucket_norms, base_levels,
    *,
    mean_bits: int,
    min_bits: int = 1,
    max_bits: int = 8,
) -> tuple:
    """Greedy per-bucket bit allocation under a mean-bits budget.

    For every candidate width ``b`` the expected quantization error of
    bucket ``i`` is ``||v_i||^2 * Psi_i(resample_levels(levels, 2**b))``
    (Eq. 3 with a single truncated-normal component) — closed form in
    the same sufficient statistics the adaptive schemes already fit.
    Allocation starts everywhere at ``min_bits`` and greedily grants
    +1 scheme bit to the bucket with the largest error reduction per
    wire bit until the budget ``nb * wire_bits(2**mean_bits)`` is
    spent.  High-variance / high-norm buckets end up with more levels.

    Returns a per-bucket scheme-bits tuple for ``MixedWidthCodec``.
    """
    mu = np.asarray(mu, np.float64)
    sigma = np.asarray(sigma, np.float64)
    w2 = np.asarray(bucket_norms, np.float64) ** 2
    nb = mu.shape[0]
    base = jnp.asarray(base_levels, jnp.float32)

    err = {}
    for b in range(min_bits, max_bits + 1):
        lv = resample_levels(base, _num_levels_for_bits(b))
        psi = jax.vmap(lambda m, s: expected_variance(
            TruncNormStats(mu=m[None], sigma=s[None],
                           gamma=jnp.ones((1,), jnp.float32)), lv))(
            jnp.asarray(mu, jnp.float32), jnp.asarray(sigma, jnp.float32))
        err[b] = np.asarray(psi, np.float64) * w2

    def wire(b):
        return packing.wire_bits_for(_num_levels_for_bits(b))

    budget = nb * wire(mean_bits)
    widths = np.full(nb, min_bits, np.int64)
    cost = nb * wire(min_bits)

    heap = []
    for i in range(nb):
        if min_bits < max_bits:
            dw = wire(min_bits + 1) - wire(min_bits)
            gain = (err[min_bits][i] - err[min_bits + 1][i]) / max(dw, 1)
            heapq.heappush(heap, (-gain, i, min_bits + 1, dw))
    while heap:
        neg_gain, i, b_next, dw = heapq.heappop(heap)
        if widths[i] != b_next - 1 or cost + dw > budget:
            continue
        widths[i] = b_next
        cost += dw
        if b_next < max_bits:
            dw2 = wire(b_next + 1) - wire(b_next)
            gain = (err[b_next][i] - err[b_next + 1][i]) / max(dw2, 1)
            heapq.heappush(heap, (-gain, i, b_next + 1, dw2))
    return tuple(int(b) for b in widths)


def mixed_widths_from_gradient(flat, scheme, *,
                               use_pallas: bool = False) -> tuple:
    """The probe-step protocol: one gradient -> a width assignment.

    One fused ``bucket_stats`` sweep over the (codec-aligned) buckets of
    ``flat``, a conditioning floor on sigma, then ``assign_mixed_widths``
    under the scheme's own mean-bits budget.  Shared by the simulator's
    ``mixed_width`` scenario and ``benchmarks/bench_mixed_bits.py`` so
    the committed benchmark measures exactly what the scenario runs.
    """
    from repro.kernels import ops
    flat = jnp.asarray(flat).reshape(-1)
    codec = codec_for_scheme(scheme)
    vb = codec.bucketize(flat, codec.plan(flat.shape[0]))
    norms, mu, var = ops.bucket_stats_op(vb, norm_type=scheme.norm_type,
                                         use_pallas=use_pallas)
    # alignment padding is all-zero; keep only fully-populated buckets
    nb_valid = max(flat.shape[0] // scheme.bucket_size, 1)
    return assign_mixed_widths(
        np.asarray(mu[:nb_valid]),
        np.clip(np.sqrt(np.asarray(var[:nb_valid])), 1e-4, None),
        np.asarray(norms[:nb_valid]),
        scheme.init_levels(), mean_bits=scheme.bits)


# ---------------------------------------------------------------------------
# factories
# ---------------------------------------------------------------------------

def codec_for_scheme(scheme) -> UniformCodec:
    """The production codec of a ``QuantScheme``: its global width."""
    return UniformCodec(num_levels=scheme.num_levels,
                        bucket_size=scheme.bucket_size,
                        norm_type=scheme.norm_type,
                        norm_dtype=scheme.norm_dtype)


def requant_codec(codec: GradientCodec, bits: int) -> UniformCodec:
    """The fixed re-quantization grid layered on top of a base codec:
    uniform ``bits``-bit levels under L-inf bucket norms, same bucketing
    and norm side-channel as the base.  Used by the two_phase broadcast
    hop and the param_server downlink."""
    from .quantize import NORM_LINF
    return UniformCodec(num_levels=_num_levels_for_bits(bits),
                        bucket_size=codec.bucket_size,
                        norm_type=NORM_LINF,
                        norm_dtype=codec.norm_dtype,
                        integrity=codec.integrity)


def make_codec(scheme, kind: str = "uniform",
               widths: tuple = (), *,
               integrity: bool = False) -> GradientCodec:
    """Codec selection as configured on ``TrainConfig`` / sim scenarios.

    ``kind='mixed_width'`` with an empty ``widths`` falls back to the
    budget-neutral ``(bits-1, bits+1)`` alternating pattern: wire widths
    are ``scheme_bits + 1``, so the two-bucket cycle ships exactly the
    same mean bits/coordinate as the uniform codec at ``scheme.bits``.
    At the range edges (bits 1 or 8), where no symmetric cycle exists,
    the fallback degenerates to the uniform-width ``(bits,)`` pattern —
    still budget-exact.

    ``kind='entropy[:base]'`` wraps the base codec (only ``uniform``
    today) in the canonical-Huffman entropy coder with the
    gaussian-prior cold-start table (``entropy_codec_for_scheme``) —
    decodable and already compressing from step 0; a table fitted to
    real occupancies is installed by the probe protocols
    (``entropy_codec_from_gradient`` / the sim's ``entropy_coded``
    scenario).
    """
    if kind == "uniform":
        codec = codec_for_scheme(scheme)
        if integrity:
            codec = dataclasses.replace(codec, integrity=True)
        return codec
    if kind == "entropy" or kind.startswith("entropy:"):
        base_kind = kind.partition(":")[2] or "uniform"
        if base_kind != "uniform":
            raise ValueError(
                f"entropy coding supports base codec 'uniform', got "
                f"{base_kind!r} (mixed-width/sparse symbol streams are "
                "not single-alphabet)")
        codec = entropy_codec_for_scheme(scheme)
        if integrity:
            codec = dataclasses.replace(codec, integrity=True)
        return codec
    if kind == "mixed_width":
        if integrity:
            raise ValueError(
                "integrity=True is not supported for codec kind "
                "'mixed_width' (no per-bucket checksum slot in the "
                "ragged width-group stream)")
        if not widths:
            if scheme.bits - 1 < 1 or scheme.bits + 1 > 8:
                widths = (scheme.bits,)
            else:
                widths = (scheme.bits - 1, scheme.bits + 1)
        return MixedWidthCodec(bucket_size=scheme.bucket_size,
                               norm_type=scheme.norm_type,
                               norm_dtype=scheme.norm_dtype,
                               widths=tuple(int(b) for b in widths))
    raise ValueError(f"unknown codec kind {kind!r}; "
                     "known: ('uniform', 'mixed_width', "
                     "'entropy[:base]')")

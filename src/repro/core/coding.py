"""Entropy coding / code-length accounting (paper App. D, Thm 3).

The device wire format is fixed-width packed indices (packing.py); this
module provides the paper's *expected-bits* accounting — closed-form
level occupancy probabilities Pr(l_j) (Prop. 6), their entropy H(L), a
real host-side Huffman code built from those probabilities, and the
Thm-3 bound  E|ENCODE(v)| <= b + n_{l1,d} + d (H(L) + 1) — plus the
static canonical-Huffman *wire table* (``entropy_table``) that
``core.codec.EntropyCodec`` uses to realize that cost as actual coded
bytes.
"""
from __future__ import annotations

import heapq
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from .stats import TruncNormStats, partial_moment0, partial_moment1


def level_probabilities(levels: jnp.ndarray, stats: TruncNormStats) -> jnp.ndarray:
    """Pr(l_j) under randomized rounding (Prop. 6), closed form.

    Pr(l_j) = int_{l_{j-1}}^{l_j} (r-l_{j-1})/(l_j-l_{j-1}) dF
            + int_{l_j}^{l_{j+1}} (l_{j+1}-r)/(l_{j+1}-l_j) dF
    with one-sided variants at the endpoints.  Returns a vector over all
    levels (including 0 and 1) summing to 1 — also in the degenerate
    edges (a single-level grid, sigma -> 0 mass collapsed onto one bin),
    where the closed form loses all its mass and the uniform
    distribution is the honest fallback.
    """
    l = levels
    n = l.shape[0]
    if n == 1:
        # one level: the symbol is deterministic
        return jnp.ones((1,), l.dtype)
    a, b = l[:-1], l[1:]  # bin edges
    gap = jnp.maximum(b - a, 1e-12)
    m0 = partial_moment0(stats, a, b)
    m1 = partial_moment1(stats, a, b)
    up = (m1 - a * m0) / gap      # mass rounded *up* from each bin
    down = (b * m0 - m1) / gap    # mass rounded *down*
    probs = jnp.zeros((n,), l.dtype)
    probs = probs.at[1:].add(up)
    probs = probs.at[:-1].add(down)
    # numerical cleanup: F may not integrate exactly to 1 on [0,1]; a
    # fully degenerate fit (all mass lost to rounding) falls back to
    # uniform occupancies rather than an all-zero "distribution"
    probs = jnp.clip(probs, 0.0, None)
    total = jnp.sum(probs)
    uniform = jnp.full((n,), 1.0 / n, l.dtype)
    return jnp.where(total > 1e-12, probs / jnp.maximum(total, 1e-12),
                     uniform)


def entropy_bits(probs: jnp.ndarray) -> jnp.ndarray:
    """H(L) in bits."""
    p = jnp.clip(probs, 1e-12, 1.0)
    return -jnp.sum(jnp.where(probs > 0, probs * jnp.log2(p), 0.0))


def huffman_code_lengths(probs: Sequence[float]) -> np.ndarray:
    """Host-side Huffman code lengths for the level symbols.

    Optimal prefix code (Thm 5): H(L) <= E[len] <= H(L) + 1.
    """
    probs = np.asarray(probs, dtype=np.float64)
    n = len(probs)
    if n == 1:
        return np.array([1])
    heap = [(float(p), i, None) for i, p in enumerate(probs)]
    heapq.heapify(heap)
    counter = n
    parents: dict[int, tuple] = {}
    while len(heap) > 1:
        p1, i1, _ = heapq.heappop(heap)
        p2, i2, _ = heapq.heappop(heap)
        parents[counter] = (i1, i2)
        heapq.heappush(heap, (p1 + p2, counter, None))
        counter += 1
    root = heap[0][1]
    lengths = np.zeros(counter, dtype=np.int64)

    stack = [(root, 0)]
    while stack:
        node, depth = stack.pop()
        if node in parents:
            l, r = parents[node]
            stack.append((l, depth + 1))
            stack.append((r, depth + 1))
        else:
            lengths[node] = max(depth, 1)
    return lengths[:n]


def expected_huffman_bits(probs: np.ndarray) -> float:
    """E[len] of the Huffman code for one magnitude symbol."""
    lengths = huffman_code_lengths(np.asarray(probs))
    return float(np.sum(np.asarray(probs) * lengths))


def expected_bits_per_coordinate(
    levels: jnp.ndarray, stats: TruncNormStats, *, use_huffman: bool = True
) -> float:
    """Expected wire bits per coordinate: magnitude symbol + sign bit for
    nonzero symbols (App. D encoding)."""
    probs = np.asarray(level_probabilities(levels, stats))
    mag = expected_huffman_bits(probs) if use_huffman else float(
        np.ceil(np.log2(len(probs)))
    )
    p_nonzero = 1.0 - probs[0]
    return mag + p_nonzero  # one sign bit whenever the symbol is nonzero


# ---------------------------------------------------------------------------
# canonical-Huffman wire table (consumed by core.codec.EntropyCodec)
# ---------------------------------------------------------------------------

# Longest wire codeword the variable-length packer supports: a codeword
# must fit one uint32 so that, at any bit offset, it spills into at most
# one following word (the same two-scatter invariant packing.pack uses).
MAX_CODE_BITS = 32

# Probability floor applied before building the wire table: bounds the
# depth of the Huffman tree (a symbol with floored probability p gets a
# code no longer than ~log2(1/p) + alphabet slack), so even never-seen
# symbols keep codeword lengths far inside MAX_CODE_BITS.
_PROB_FLOOR = 2.0 ** -20


def signed_symbol_probabilities(level_probs: Sequence[float]) -> np.ndarray:
    """Magnitude-level occupancies -> the joint *signed-symbol* alphabet.

    The wire alphabet is the ``2L - 1`` biased signed indices
    (``packing.bias_codes``): symbol ``L - 1`` is the shared zero, and
    level ``j > 0`` splits into +/- with half its mass each (stochastic
    rounding is sign-symmetric).  The joint entropy is exactly
    ``H(L) + Pr(sym != 0)`` — the metered ``SchemeState.entropy_bits``
    accounting — so a Huffman code on this alphabet realizes the metered
    cost to within the usual < 1 bit/symbol redundancy.
    """
    p = np.asarray(level_probs, np.float64)
    L = p.shape[0]
    joint = np.empty(2 * L - 1, np.float64)
    joint[L - 1] = p[0]
    for j in range(1, L):
        joint[L - 1 + j] = joint[L - 1 - j] = p[j] / 2.0
    return joint


def canonical_code(lengths: Sequence[int]) -> np.ndarray:
    """Canonical prefix codewords from code lengths, bit-reversed for an
    LSB-first wire.

    Symbols are ranked by ``(length, symbol)`` and assigned consecutive
    MSB-first canonical values (the textbook construction; valid for any
    Kraft-satisfying length vector).  Each value is then bit-reversed
    within its length, so a packer that emits codeword bit 0 first — the
    little-endian-in-word convention of ``packing.pack`` — transmits the
    canonical code MSB-first on the wire (the DEFLATE trick).
    """
    lengths = np.asarray(lengths, np.int64)
    S = lengths.shape[0]
    order = sorted(range(S), key=lambda s: (lengths[s], s))
    codes = np.zeros(S, np.uint64)
    code = 0
    prev = int(lengths[order[0]])
    for s in order:
        code <<= int(lengths[s]) - prev
        prev = int(lengths[s])
        rev = 0
        for b in range(prev):  # bit-reverse within the code length
            rev = (rev << 1) | ((code >> b) & 1)
        codes[s] = rev
        code += 1
    return codes.astype(np.uint32)


def entropy_table(level_probs: Sequence[float] | None,
                  num_levels: int) -> tuple[tuple, tuple]:
    """(lengths, wire codewords) for the signed-symbol alphabet.

    ``level_probs=None`` builds the cold-start table from uniform joint
    occupancies (codeword lengths ~ the fixed wire width), so an
    ``EntropyCodec`` is decodable before any statistics exist.  The
    table is returned as hashable int tuples — it is *static* codec
    configuration, baked into the trace like a mixed-width pattern.
    """
    S = 2 * num_levels - 1
    if level_probs is None:
        joint = np.full(S, 1.0 / S, np.float64)
    else:
        p = np.asarray(level_probs, np.float64)
        if p.shape[0] != num_levels:
            raise ValueError(
                f"level_probs has {p.shape[0]} levels, codec has "
                f"{num_levels}")
        joint = signed_symbol_probabilities(p)
    joint = np.clip(joint, _PROB_FLOOR, None)
    joint = joint / joint.sum()
    lengths = huffman_code_lengths(joint)
    if int(lengths.max()) > MAX_CODE_BITS:
        # pathological skew: fall back to a fixed-width (still
        # prefix-free) table rather than over-long codewords
        from .packing import wire_bits_for
        lengths = np.full(S, wire_bits_for(num_levels), np.int64)
    codes = canonical_code(lengths)
    return (tuple(int(x) for x in lengths),
            tuple(int(x) for x in codes))


def code_length_bound(
    levels: jnp.ndarray,
    stats: TruncNormStats,
    d: int,
    *,
    q: float = 2.0,
    norm_bits: int = 32,
) -> float:
    """Thm 3 upper bound: b + n_{l1,d} + d (H(L) + 1)."""
    probs = level_probabilities(levels, stats)
    H = float(entropy_bits(probs))
    l1 = float(levels[1]) if levels.shape[0] > 1 else 1.0
    n_l1_d = min(l1 ** (-q) + d ** (1.0 - 1.0 / q) / l1, float(d))
    return norm_bits + n_l1_d + d * (H + 1.0)

"""Entropy coding / code-length accounting (paper App. D, Thm 3).

The device wire format is fixed-width packed indices (packing.py); this
module provides the paper's *expected-bits* accounting: closed-form level
occupancy probabilities Pr(l_j) (Prop. 6), their entropy H(L), a real
host-side Huffman code built from those probabilities, and the Thm-3
bound  E|ENCODE(v)| <= b + n_{l1,d} + d (H(L) + 1).
"""
from __future__ import annotations

import heapq
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from .stats import TruncNormStats, partial_moment0, partial_moment1


def level_probabilities(levels: jnp.ndarray, stats: TruncNormStats) -> jnp.ndarray:
    """Pr(l_j) under randomized rounding (Prop. 6), closed form.

    Pr(l_j) = int_{l_{j-1}}^{l_j} (r-l_{j-1})/(l_j-l_{j-1}) dF
            + int_{l_j}^{l_{j+1}} (l_{j+1}-r)/(l_{j+1}-l_j) dF
    with one-sided variants at the endpoints.  Returns a vector over all
    levels (including 0 and 1) summing to 1.
    """
    l = levels
    n = l.shape[0]
    a, b = l[:-1], l[1:]  # bin edges
    gap = jnp.maximum(b - a, 1e-12)
    m0 = partial_moment0(stats, a, b)
    m1 = partial_moment1(stats, a, b)
    up = (m1 - a * m0) / gap      # mass rounded *up* from each bin
    down = (b * m0 - m1) / gap    # mass rounded *down*
    probs = jnp.zeros((n,), l.dtype)
    probs = probs.at[1:].add(up)
    probs = probs.at[:-1].add(down)
    # numerical cleanup: F may not integrate exactly to 1 on [0,1]
    probs = jnp.clip(probs, 0.0, None)
    return probs / jnp.maximum(jnp.sum(probs), 1e-12)


def entropy_bits(probs: jnp.ndarray) -> jnp.ndarray:
    """H(L) in bits."""
    p = jnp.clip(probs, 1e-12, 1.0)
    return -jnp.sum(jnp.where(probs > 0, probs * jnp.log2(p), 0.0))


def huffman_code_lengths(probs: Sequence[float]) -> np.ndarray:
    """Host-side Huffman code lengths for the level symbols.

    Optimal prefix code (Thm 5): H(L) <= E[len] <= H(L) + 1.
    """
    probs = np.asarray(probs, dtype=np.float64)
    n = len(probs)
    if n == 1:
        return np.array([1])
    heap = [(float(p), i, None) for i, p in enumerate(probs)]
    heapq.heapify(heap)
    counter = n
    parents: dict[int, tuple] = {}
    while len(heap) > 1:
        p1, i1, _ = heapq.heappop(heap)
        p2, i2, _ = heapq.heappop(heap)
        parents[counter] = (i1, i2)
        heapq.heappush(heap, (p1 + p2, counter, None))
        counter += 1
    root = heap[0][1]
    lengths = np.zeros(counter, dtype=np.int64)

    stack = [(root, 0)]
    while stack:
        node, depth = stack.pop()
        if node in parents:
            l, r = parents[node]
            stack.append((l, depth + 1))
            stack.append((r, depth + 1))
        else:
            lengths[node] = max(depth, 1)
    return lengths[:n]


def expected_huffman_bits(probs: np.ndarray) -> float:
    """E[len] of the Huffman code for one magnitude symbol."""
    lengths = huffman_code_lengths(np.asarray(probs))
    return float(np.sum(np.asarray(probs) * lengths))


def expected_bits_per_coordinate(
    levels: jnp.ndarray, stats: TruncNormStats, *, use_huffman: bool = True
) -> float:
    """Expected wire bits per coordinate: magnitude symbol + sign bit for
    nonzero symbols (App. D encoding)."""
    probs = np.asarray(level_probabilities(levels, stats))
    mag = expected_huffman_bits(probs) if use_huffman else float(
        np.ceil(np.log2(len(probs)))
    )
    p_nonzero = 1.0 - probs[0]
    return mag + p_nonzero  # one sign bit whenever the symbol is nonzero


def code_length_bound(
    levels: jnp.ndarray,
    stats: TruncNormStats,
    d: int,
    *,
    q: float = 2.0,
    norm_bits: int = 32,
) -> float:
    """Thm 3 upper bound: b + n_{l1,d} + d (H(L) + 1)."""
    probs = level_probabilities(levels, stats)
    H = float(entropy_bits(probs))
    l1 = float(levels[1]) if levels.shape[0] > 1 else 1.0
    n_l1_d = min(l1 ** (-q) + d ** (1.0 - 1.0 / q) / l1, float(d))
    return norm_bits + n_l1_d + d * (H + 1.0)

"""Quantization level grids (the object ALQ/AMQ adapt).

A level vector is ``l = [l0=0, l1, ..., ls, l_{s+1}=1]`` on the unit
interval, applied to *normalized magnitudes* ``r = |v_i| / ||v||``; the
sign is carried separately (paper Sec. 3).  For ``bits`` b we follow the
paper's convention of ``2**b`` levels on [0, 1] (so s = 2**b - 2 interior
adaptable levels); the wire format then spends b bits on the magnitude
symbol plus one sign bit for nonzero symbols (see coding.py / packing.py).
"""
from __future__ import annotations

import jax.numpy as jnp


def num_levels(bits: int) -> int:
    """Total number of points on [0,1] (including 0 and 1)."""
    if bits < 1:
        raise ValueError(f"bits must be >= 1, got {bits}")
    return 2 ** bits


def num_inner(bits: int) -> int:
    """Number of adaptable interior levels s."""
    return num_levels(bits) - 2


def uniform_levels(bits: int, dtype=jnp.float32) -> jnp.ndarray:
    """QSGD / QSGDinf grid: uniformly spaced levels on [0, 1]."""
    return jnp.linspace(0.0, 1.0, num_levels(bits), dtype=dtype)


def exp_levels(bits: int, p: float = 0.5, dtype=jnp.float32) -> jnp.ndarray:
    """NUQSGD / AMQ grid: [0, p^s, ..., p^2, p, 1] (exponentially spaced)."""
    n = num_levels(bits)
    # n-1 nonzero levels: p**(n-2), ..., p**1, p**0
    exps = jnp.arange(n - 2, -1, -1, dtype=dtype)
    pos = jnp.asarray(p, dtype) ** exps
    return jnp.concatenate([jnp.zeros((1,), dtype), pos])


def ternary_levels(dtype=jnp.float32) -> jnp.ndarray:
    """TernGrad: levels {0, 1} under L-inf normalization (s = 0)."""
    return jnp.asarray([0.0, 1.0], dtype)


def multiplier_to_levels(p: jnp.ndarray, bits: int) -> jnp.ndarray:
    """AMQ parametrization: multiplier p -> level vector [0, p^s..p, 1]."""
    n = 2 ** bits
    exps = jnp.arange(n - 2, -1, -1, dtype=jnp.result_type(p, jnp.float32))
    pos = p ** exps
    return jnp.concatenate([jnp.zeros((1,), pos.dtype), pos])


def is_feasible(levels: jnp.ndarray, eps: float = 0.0) -> jnp.ndarray:
    """l in L: strictly increasing, l0 = 0, l_{s+1} = 1."""
    ok_mono = jnp.all(levels[1:] - levels[:-1] > eps)
    ok_ends = (levels[0] == 0.0) & (levels[-1] == 1.0)
    return ok_mono & ok_ends


def level_gaps(levels: jnp.ndarray) -> jnp.ndarray:
    """delta_j = min(l_j - l_{j-1}, l_{j+1} - l_j) for interior j (Eq. 7)."""
    left = levels[1:-1] - levels[:-2]
    right = levels[2:] - levels[1:-1]
    return jnp.minimum(left, right)

"""k-bit <-> uint32 bit packing for the collective wire format.

Signed level indices in [-(L-1), +(L-1)] are biased to unsigned symbols
in [0, 2L-2] and packed ``wire_bits`` per symbol into a dense uint32
stream.  This is what actually travels over ICI in the quantized
allreduce: ``ceil(n * wire_bits / 32)`` words instead of n fp32 words.

The packer is fully vectorized (two scatter-adds per stream — one for the
low fragment of each symbol, one for the fragment spilling into the next
word), so it lowers cleanly under jit/shard_map on any backend.
"""
from __future__ import annotations

import math

import jax.numpy as jnp


def wire_bits_for(num_levels: int) -> int:
    """Bits per symbol for signed indices over `num_levels` magnitudes.

    Symbols: 2*num_levels - 1 (zero is shared between signs).
    """
    n_sym = 2 * num_levels - 1
    return max(1, math.ceil(math.log2(n_sym)))


def packed_words(n: int, bits: int) -> int:
    return -(-(n * bits) // 32)


def pack(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack unsigned symbols (int32 in [0, 2**bits)) into uint32 words."""
    codes = codes.reshape(-1).astype(jnp.uint32) & jnp.uint32((1 << bits) - 1)
    n = codes.shape[0]
    nwords = packed_words(n, bits)
    i = jnp.arange(n, dtype=jnp.uint32)
    bitpos = i * jnp.uint32(bits)
    widx = (bitpos >> 5).astype(jnp.int32)
    off = bitpos & jnp.uint32(31)
    lo = (codes << off).astype(jnp.uint32)
    # fragment spilling into the next word; shift (32-off) is invalid for
    # off == 0, so route through a masked shift.
    spill_shift = jnp.where(off > 0, jnp.uint32(32) - off, jnp.uint32(31))
    hi = jnp.where(off > 0, codes >> spill_shift, jnp.uint32(0))
    # scatter into nwords+1 (spill slot), then drop the spill word — it is
    # always zero when the stream length is exact.
    out = jnp.zeros((nwords + 1,), jnp.uint32)
    out = out.at[widx].add(lo, mode="promise_in_bounds")
    out = out.at[widx + 1].add(hi, mode="promise_in_bounds")
    return out[:nwords]


def unpack(words: jnp.ndarray, n: int, bits: int) -> jnp.ndarray:
    """Inverse of pack: recover n unsigned symbols (int32)."""
    words = jnp.concatenate(
        [words.astype(jnp.uint32), jnp.zeros((1,), jnp.uint32)])
    i = jnp.arange(n, dtype=jnp.uint32)
    bitpos = i * jnp.uint32(bits)
    widx = (bitpos >> 5).astype(jnp.int32)
    off = bitpos & jnp.uint32(31)
    lo = words[widx] >> off
    spill_shift = jnp.where(off > 0, jnp.uint32(32) - off, jnp.uint32(31))
    hi = jnp.where(off > 0, words[widx + 1] << spill_shift, jnp.uint32(0))
    mask = jnp.uint32((1 << bits) - 1)
    return ((lo | hi) & mask).astype(jnp.int32)


def bias_codes(signed_codes: jnp.ndarray, num_levels: int) -> jnp.ndarray:
    """Signed index in [-(L-1), L-1] -> unsigned symbol in [0, 2L-2]."""
    return (signed_codes.astype(jnp.int32) + (num_levels - 1)).astype(jnp.int32)


def unbias_codes(symbols: jnp.ndarray, num_levels: int) -> jnp.ndarray:
    return symbols.astype(jnp.int32) - (num_levels - 1)


def pack_signed(signed_codes: jnp.ndarray, num_levels: int) -> jnp.ndarray:
    bits = wire_bits_for(num_levels)
    return pack(bias_codes(signed_codes, num_levels), bits)


def unpack_signed(words: jnp.ndarray, n: int, num_levels: int) -> jnp.ndarray:
    bits = wire_bits_for(num_levels)
    return unbias_codes(unpack(words, n, bits), num_levels)

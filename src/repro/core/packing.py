"""k-bit <-> uint32 bit packing for the collective wire format.

Signed level indices in [-(L-1), +(L-1)] are biased to unsigned symbols
in [0, 2L-2] and packed ``wire_bits`` per symbol into a dense uint32
stream.  This is what actually travels over ICI in the quantized
allreduce: ``ceil(n * wire_bits / 32)`` words instead of n fp32 words.

The packer is fully vectorized (two scatter-adds per stream — one for the
low fragment of each symbol, one for the fragment spilling into the next
word), so it lowers cleanly under jit/shard_map on any backend.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def wire_bits_for(num_levels: int) -> int:
    """Bits per symbol for signed indices over `num_levels` magnitudes.

    Symbols: 2*num_levels - 1 (zero is shared between signs).
    """
    n_sym = 2 * num_levels - 1
    return max(1, math.ceil(math.log2(n_sym)))


def packed_words(n: int, bits: int) -> int:
    return -(-(n * bits) // 32)


def pack(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack unsigned symbols (int32 in [0, 2**bits)) into uint32 words."""
    codes = codes.reshape(-1).astype(jnp.uint32) & jnp.uint32((1 << bits) - 1)
    n = codes.shape[0]
    nwords = packed_words(n, bits)
    i = jnp.arange(n, dtype=jnp.uint32)
    bitpos = i * jnp.uint32(bits)
    widx = (bitpos >> 5).astype(jnp.int32)
    off = bitpos & jnp.uint32(31)
    lo = (codes << off).astype(jnp.uint32)
    # fragment spilling into the next word; shift (32-off) is invalid for
    # off == 0, so route through a masked shift.
    spill_shift = jnp.where(off > 0, jnp.uint32(32) - off, jnp.uint32(31))
    hi = jnp.where(off > 0, codes >> spill_shift, jnp.uint32(0))
    # scatter into nwords+1 (spill slot), then drop the spill word — it is
    # always zero when the stream length is exact.
    out = jnp.zeros((nwords + 1,), jnp.uint32)
    out = out.at[widx].add(lo, mode="promise_in_bounds")
    out = out.at[widx + 1].add(hi, mode="promise_in_bounds")
    return out[:nwords]


def unpack(words: jnp.ndarray, n: int, bits: int) -> jnp.ndarray:
    """Inverse of pack: recover n unsigned symbols (int32)."""
    words = jnp.concatenate(
        [words.astype(jnp.uint32), jnp.zeros((1,), jnp.uint32)])
    i = jnp.arange(n, dtype=jnp.uint32)
    bitpos = i * jnp.uint32(bits)
    widx = (bitpos >> 5).astype(jnp.int32)
    off = bitpos & jnp.uint32(31)
    lo = words[widx] >> off
    spill_shift = jnp.where(off > 0, jnp.uint32(32) - off, jnp.uint32(31))
    hi = jnp.where(off > 0, words[widx + 1] << spill_shift, jnp.uint32(0))
    mask = jnp.uint32((1 << bits) - 1)
    return ((lo | hi) & mask).astype(jnp.int32)


def bias_codes(signed_codes: jnp.ndarray, num_levels: int) -> jnp.ndarray:
    """Signed index in [-(L-1), L-1] -> unsigned symbol in [0, 2L-2]."""
    return (signed_codes.astype(jnp.int32) + (num_levels - 1)).astype(jnp.int32)


def unbias_codes(symbols: jnp.ndarray, num_levels: int) -> jnp.ndarray:
    return symbols.astype(jnp.int32) - (num_levels - 1)


NORM_DTYPES = ("float32", "float16")


def norm_words(nb: int, norm_dtype: str = "float32") -> int:
    """uint32 words occupied by ``nb`` packed bucket norms."""
    if norm_dtype == "float32":
        return nb
    if norm_dtype == "float16":
        return -(-nb // 2)
    raise ValueError(f"unknown norm_dtype {norm_dtype!r}; known: {NORM_DTYPES}")


def pack_norms(norms: jnp.ndarray, norm_dtype: str = "float32") -> jnp.ndarray:
    """Bucket norms -> dense uint32 word stream for the wire.

    ``float32`` is a pure bitcast (1 word/norm).  ``float16`` halves the
    norm side-channel: norms are rounded to fp16 (gradient bucket norms
    sit far inside fp16's range; the ~2^-11 relative step is below
    quantization noise at every practical width) and packed two per word,
    little-end first.
    """
    norms = norms.reshape(-1)
    if norm_dtype == "float32":
        return jax.lax.bitcast_convert_type(norms.astype(jnp.float32),
                                            jnp.uint32)
    if norm_dtype == "float16":
        h = jax.lax.bitcast_convert_type(norms.astype(jnp.float16),
                                         jnp.uint16).astype(jnp.uint32)
        nb = h.shape[0]
        if nb % 2:
            h = jnp.concatenate([h, jnp.zeros((1,), jnp.uint32)])
        pair = h.reshape(-1, 2)
        return pair[:, 0] | (pair[:, 1] << jnp.uint32(16))
    raise ValueError(f"unknown norm_dtype {norm_dtype!r}; known: {NORM_DTYPES}")


def unpack_norms(words: jnp.ndarray, nb: int,
                 norm_dtype: str = "float32") -> jnp.ndarray:
    """Inverse of ``pack_norms``: recover ``nb`` fp32 bucket norms
    (fp16 norms are upcast; the fp16 rounding itself is lossy by design)."""
    if norm_dtype == "float32":
        return jax.lax.bitcast_convert_type(words, jnp.float32)[:nb]
    if norm_dtype == "float16":
        lo = (words & jnp.uint32(0xFFFF)).astype(jnp.uint16)
        hi = (words >> jnp.uint32(16)).astype(jnp.uint16)
        h = jnp.stack([lo, hi], axis=-1).reshape(-1)[:nb]
        return jax.lax.bitcast_convert_type(h, jnp.float16).astype(jnp.float32)
    raise ValueError(f"unknown norm_dtype {norm_dtype!r}; known: {NORM_DTYPES}")


# ---------------------------------------------------------------------------
# wire integrity words
# ---------------------------------------------------------------------------

# Mixing constants for the per-bucket integrity word (odd, so every
# per-position multiplier is invertible mod 2^32: any single-symbol
# change provably changes the weighted sum).
_CSUM_SYM_MULT = 0x9E3779B1   # golden-ratio odd constant
_CSUM_NORM_MULT = 0x85EBCA6B  # Murmur3 fmix constant
# Nonzero offset so the ALL-ZERO payload (a dropped/zeroed wire row:
# symbols 0, norm bits 0, stored checksum word 0) does NOT checksum to
# 0 — zero rows are detected as invalid instead of decoding as a
# "valid" zero bucket.
_CSUM_OFFSET = 0x6A09E667


def norm_bit_patterns(norms: jnp.ndarray,
                      norm_dtype: str = "float32") -> jnp.ndarray:
    """Per-bucket wire bit pattern of each norm, as uint32.

    This is what the integrity word covers for the norm side-channel:
    the exact bits that travel (fp16 norms contribute their 16-bit
    pattern), so recomputing it from DECODED norms matches iff the norm
    words arrived intact.  fp32 decoded norms round-trip to fp16
    exactly (they were produced by an exact upcast).
    """
    norms = norms.reshape(-1)
    if norm_dtype == "float32":
        return jax.lax.bitcast_convert_type(norms.astype(jnp.float32),
                                            jnp.uint32)
    if norm_dtype == "float16":
        return jax.lax.bitcast_convert_type(
            norms.astype(jnp.float16), jnp.uint16).astype(jnp.uint32)
    raise ValueError(f"unknown norm_dtype {norm_dtype!r}; known: {NORM_DTYPES}")


def bucket_checksums(symbols: jnp.ndarray,
                     norm_bits: jnp.ndarray) -> jnp.ndarray:
    """(nb, bucket_size) unsigned symbols + (nb,) norm bit patterns ->
    (nb,) uint32 integrity words.

    A position-weighted sum with distinct ODD multipliers per
    coordinate (so any single-symbol change flips the sum with
    certainty; independent multi-word corruption escapes with
    probability ~2^-32), mixed with an xorshift-multiply avalanche.
    Fully vectorized — no scan — so the integrity pass costs one
    elementwise multiply-reduce per bucket.
    """
    sym = symbols.astype(jnp.uint32)
    bs = sym.shape[-1]
    i = jnp.arange(bs, dtype=jnp.uint32)
    mult = (jnp.uint32(2) * i + jnp.uint32(1)) * jnp.uint32(_CSUM_SYM_MULT)
    h = jnp.sum(sym * mult[None, :], axis=-1, dtype=jnp.uint32)
    h = h + norm_bits.astype(jnp.uint32) * jnp.uint32(_CSUM_NORM_MULT)
    h = h + jnp.uint32(_CSUM_OFFSET)
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> jnp.uint32(15))
    return h


def pack_signed(signed_codes: jnp.ndarray, num_levels: int) -> jnp.ndarray:
    bits = wire_bits_for(num_levels)
    return pack(bias_codes(signed_codes, num_levels), bits)


def unpack_signed(words: jnp.ndarray, n: int, num_levels: int) -> jnp.ndarray:
    bits = wire_bits_for(num_levels)
    return unbias_codes(unpack(words, n, bits), num_levels)

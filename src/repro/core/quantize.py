"""Bucketed stochastic quantization Q_l (paper Sec. 3).

A flat gradient is padded to a multiple of ``bucket_size``, reshaped to
(num_buckets, bucket_size), and each bucket is normalized by its own Lq
norm (the "bucketing trick", Sec. 5).  Each normalized magnitude is
stochastically rounded to one of the levels; the wire representation is a
*signed level index* (int8 — see ``code_dtype``) plus one fp32 norm per
bucket.

``encode`` / ``decode`` are the reference (pure-jnp) pair; the Pallas
kernels in ``repro.kernels`` implement the same contract with VMEM
tiling and are tested against these.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

NORM_L2 = "l2"
NORM_LINF = "linf"
NORM_L1 = "l1"


class QuantizedTensor(NamedTuple):
    """Wire representation of one quantized (bucketed) tensor."""

    codes: jnp.ndarray  # (num_buckets, bucket_size) int8 signed level index
    norms: jnp.ndarray  # (num_buckets,) f32 bucket norms
    dim: int            # original (unpadded) length


def code_dtype(num_levels: int):
    """Dtype of signed level indices in [-(L-1), L-1].

    int8 covers every grid up to 128 levels (bits <= 7); only the 8-bit
    edge (256 levels, |index| up to 255) needs int16.  Using the narrow
    dtype halves the pre-pack HBM footprint on the paper's operating
    points (2-4 bits).
    """
    return jnp.int8 if num_levels <= 128 else jnp.int16


def bucket_norm(vb: jnp.ndarray, norm_type: str) -> jnp.ndarray:
    """Per-bucket Lq norm; vb is (num_buckets, bucket_size)."""
    if norm_type == NORM_L2:
        return jnp.sqrt(jnp.sum(vb * vb, axis=-1))
    if norm_type == NORM_LINF:
        return jnp.max(jnp.abs(vb), axis=-1)
    if norm_type == NORM_L1:
        return jnp.sum(jnp.abs(vb), axis=-1)
    raise ValueError(f"unknown norm {norm_type!r}")


def pad_to_buckets(v: jnp.ndarray, bucket_size: int) -> jnp.ndarray:
    """Flatten and zero-pad to a bucket multiple -> (nb, bucket_size)."""
    flat = v.reshape(-1)
    d = flat.shape[0]
    nb = -(-d // bucket_size)
    pad = nb * bucket_size - d
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(nb, bucket_size)


def normalized_magnitudes(
    v: jnp.ndarray, bucket_size: int, norm_type: str
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (r, norms): r in [0,1], shape (nb, bucket_size)."""
    vb = pad_to_buckets(v, bucket_size)
    norms = bucket_norm(vb, norm_type)
    safe = jnp.where(norms > 0, norms, 1.0)
    r = jnp.abs(vb) / safe[:, None]
    # Lq with q < inf can still give r>1 only for q<... never for q>=1 on
    # single coords, but guard against fp slop.
    return jnp.clip(r, 0.0, 1.0), norms


def clip_coordinates(v: jnp.ndarray, clip_sigmas: float) -> jnp.ndarray:
    """TernGrad-style pre-quantization clipping (paper Eq. 49)."""
    flat = v.reshape(v.shape)
    sigma = jnp.std(flat)
    c = clip_sigmas * sigma
    return jnp.clip(flat, -c, c)


def stochastic_round(
    r: jnp.ndarray, levels: jnp.ndarray, u: jnp.ndarray
) -> jnp.ndarray:
    """Map r in [0,1] to a level *index* with unbiased randomized rounding.

    u ~ Uniform[0,1) of the same shape supplies the randomness (kept as an
    explicit input so the Pallas kernel and the oracle share it exactly).
    """
    nlev = levels.shape[0]
    tau = jnp.clip(jnp.searchsorted(levels, r, side="right") - 1, 0, nlev - 2)
    lo = levels[tau]
    hi = levels[tau + 1]
    rho = (r - lo) / jnp.maximum(hi - lo, 1e-30)
    return (tau + (u < rho)).astype(jnp.int32)


def encode(
    v: jnp.ndarray,
    levels: jnp.ndarray,
    key: jax.Array,
    *,
    bucket_size: int,
    norm_type: str = NORM_L2,
) -> QuantizedTensor:
    """ENCODE_l(v): signed level indices + bucket norms."""
    d = v.size
    r, norms = normalized_magnitudes(v, bucket_size, norm_type)
    u = jax.random.uniform(key, r.shape, dtype=r.dtype)
    idx = stochastic_round(r, levels, u)
    sign = jnp.sign(pad_to_buckets(v, bucket_size))
    codes = (idx * sign).astype(code_dtype(levels.shape[0]))
    return QuantizedTensor(codes=codes, norms=norms.astype(jnp.float32), dim=d)


def decode(qt: QuantizedTensor, levels: jnp.ndarray) -> jnp.ndarray:
    """DECODE_l: back to a flat float vector of length qt.dim."""
    idx = jnp.abs(qt.codes.astype(jnp.int32))
    mags = levels[idx] * qt.norms[:, None]
    vals = mags * jnp.sign(qt.codes.astype(levels.dtype))
    return vals.reshape(-1)[: qt.dim]


def quantize(
    v: jnp.ndarray,
    levels: jnp.ndarray,
    key: jax.Array,
    *,
    bucket_size: int,
    norm_type: str = NORM_L2,
) -> jnp.ndarray:
    """Q_l(v) = DECODE(ENCODE(v)) with the original shape restored."""
    qt = encode(v, levels, key, bucket_size=bucket_size, norm_type=norm_type)
    return decode(qt, levels).reshape(v.shape)


def quantization_variance(
    v: jnp.ndarray,
    levels: jnp.ndarray,
    *,
    bucket_size: int,
    norm_type: str = NORM_L2,
) -> jnp.ndarray:
    """Exact E_h ||Q(v) - v||^2 (Eqs. 1–2): sum over coords of
    ||v||^2 (l_{tau+1} - r)(r - l_tau)."""
    r, norms = normalized_magnitudes(v, bucket_size, norm_type)
    nlev = levels.shape[0]
    tau = jnp.clip(jnp.searchsorted(levels, r, side="right") - 1, 0, nlev - 2)
    lo, hi = levels[tau], levels[tau + 1]
    per_coord = (hi - r) * (r - lo)
    return jnp.sum(norms[:, None] ** 2 * per_coord)


@functools.partial(jax.jit, static_argnames=("bucket_size", "norm_type"))
def quantize_jit(v, levels, key, *, bucket_size, norm_type=NORM_L2):
    return quantize(v, levels, key, bucket_size=bucket_size, norm_type=norm_type)

"""Named quantization schemes: the paper's methods and all its baselines.

A scheme is (initial levels, norm type, adaptivity rule).  The adaptive
state threaded through training is a ``SchemeState`` pytree so that level
updates happen *inside* the jitted train step on the paper's sparse
schedule (iters ~100, ~2000, then every 10k — App. K "Update Schedule").

Registry:
  alq / alq_n       adaptive levels, coordinate descent   (Sec. 3.1, 3.4)
  alq_gd / alq_gd_n adaptive levels, projection-free GD   (Sec. 3.2)
  amq / amq_n       adaptive multiplier                   (Sec. 3.3)
  alq_inf / amq_inf beyond-paper: adaptive levels under L-inf bucket
                    normalization — combines QSGDinf's small norm factor
                    with the adaptive grid; dominates QSGDinf on
                    near-gaussian (transformer) gradients where the
                    paper's L2-normalized ALQ does not (bench_variance)
  qsgdinf           uniform levels, L-inf norm            [Alistarh+ 17]
  nuqsgd            exponential p=0.5, L2 norm            [Ramezani-K.+ 19]
  trn               ternary {0,1} + sign, L-inf           [Wen+ 17]
  fp32 / super_sgd  no quantization (full-precision sync)
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from . import adapt, levels as levels_lib
from .quantize import NORM_L2, NORM_LINF
from .stats import TruncNormStats

ADAPTIVE_SCHEMES = ("alq", "alq_n", "alq_gd", "alq_gd_n", "amq", "amq_n",
                    "alq_inf", "amq_inf")
FIXED_SCHEMES = ("qsgdinf", "nuqsgd", "trn")
ALL_SCHEMES = ADAPTIVE_SCHEMES + FIXED_SCHEMES + ("fp32", "super_sgd")


@dataclasses.dataclass(frozen=True)
class QuantScheme:
    """Static configuration of a quantization method."""

    name: str = "alq"
    bits: int = 3
    bucket_size: int = 8192
    clip_sigmas: float = 0.0          # 0 = off; TRN uses 2.5 (Eq. 49)
    max_stat_components: int = 64     # suff.-stat subsample (App. K)
    alq_sweeps: int = 10
    amq_gd_steps: int = 100
    norm_dtype: str = "float32"       # bucket norms on the wire (f32|f16)

    def __post_init__(self):
        if self.name not in ALL_SCHEMES:
            raise ValueError(f"unknown scheme {self.name!r}; known: {ALL_SCHEMES}")
        from .packing import NORM_DTYPES
        if self.norm_dtype not in NORM_DTYPES:
            raise ValueError(
                f"unknown norm_dtype {self.norm_dtype!r}; known: {NORM_DTYPES}")

    @property
    def quantized(self) -> bool:
        return self.name not in ("fp32", "super_sgd")

    @property
    def adaptive(self) -> bool:
        return self.name in ADAPTIVE_SCHEMES

    @property
    def norm_type(self) -> str:
        # L-inf for uniform/ternary grids (QSGDinf, TRN) and the
        # beyond-paper *_inf adaptive variants; L2 otherwise (paper).
        if self.name in ("qsgdinf", "trn") or self.name.endswith("_inf"):
            return NORM_LINF
        return NORM_L2

    @property
    def weighted_stats(self) -> bool:
        """Norm^2-weighted mixture (Sec. 3.4) vs pooled ("-N" variants)."""
        return self.adaptive and not self.name.endswith("_n")

    @property
    def _base(self) -> str:
        return self.name.replace("_inf", "")

    @property
    def num_levels(self) -> int:
        if self.name == "trn":
            return 2
        return levels_lib.num_levels(self.bits)

    def init_levels(self) -> jnp.ndarray:
        if self.name == "trn":
            return levels_lib.ternary_levels()
        if self.name in ("nuqsgd",) or self._base.startswith("amq"):
            return levels_lib.exp_levels(self.bits, p=0.5)
        # ALQ variants initialize from uniform (paper Sec. 3.1: either
        # uniform or exponential init; CD converges from both).
        return levels_lib.uniform_levels(self.bits)

    @property
    def wire_bits(self) -> int:
        """Fixed-width wire bits per magnitude+sign symbol."""
        from .packing import wire_bits_for
        return wire_bits_for(self.num_levels)

    def init_state(self) -> "SchemeState":
        return SchemeState(
            levels=self.init_levels(),
            multiplier=jnp.asarray(0.5, jnp.float32),
            num_updates=jnp.asarray(0, jnp.int32),
            # until the first fit, the achievable wire cost is the
            # fixed-width cost (no occupancy statistics yet)
            entropy_bits=jnp.asarray(float(self.wire_bits), jnp.float32),
        )

    def _entropy_bits(self, levels: jnp.ndarray,
                      stats: TruncNormStats) -> jnp.ndarray:
        """Achievable entropy-coded wire bits per coordinate at these
        levels under the fitted distribution: H(L) plus one sign bit
        whenever the magnitude symbol is nonzero (App. D accounting)."""
        from .coding import entropy_bits, level_probabilities
        probs = level_probabilities(levels, stats)
        return (entropy_bits(probs) + 1.0 - probs[0]).astype(jnp.float32)

    def update_state(self, state: "SchemeState", stats: TruncNormStats) -> "SchemeState":
        """One level-adaptation step from fresh sufficient statistics."""
        if not self.adaptive:
            return state
        if self._base.startswith("amq"):
            p = adapt.amq_update(
                state.multiplier, stats, bits=self.bits, steps=self.amq_gd_steps
            )
            lv = levels_lib.multiplier_to_levels(p, self.bits)
            return SchemeState(lv, p, state.num_updates + 1,
                               self._entropy_bits(lv, stats))
        if self._base.startswith("alq_gd"):
            lv = adapt.alq_gd_update(state.levels, stats)
        else:
            lv = adapt.alq_update(state.levels, stats, sweeps=self.alq_sweeps)
        return SchemeState(lv, state.multiplier, state.num_updates + 1,
                           self._entropy_bits(lv, stats))


class SchemeState(NamedTuple):
    """Adaptive-quantization state carried in the train state pytree."""

    levels: jnp.ndarray
    multiplier: jnp.ndarray
    num_updates: jnp.ndarray
    # achievable entropy-coded wire bits/coord of the current grid, fit
    # from the stats of the last level update (H(L) + sign bits); starts
    # at the fixed-width cost.  Reported next to the actual (measured)
    # wire cost in SyncMetrics.entropy_bits_per_coord — and realized as
    # bytes by core.codec.EntropyCodec.  The default is a float32
    # SCALAR (not a Python float) so harnesses that construct the state
    # positionally keep a uniform metric dtype.
    entropy_bits: jnp.ndarray = jnp.float32(0.0)


def default_update_schedule(total_steps: int) -> tuple[int, ...]:
    """Paper App. K: update at 100, 2000, then every 10k iterations."""
    pts = [p for p in (100, 2000) if p < total_steps]
    pts += list(range(10_000, total_steps, 10_000))
    return tuple(pts)

"""Sufficient statistics of normalized gradient coordinates.

The paper models normalized coordinates ``r = |v_i|/||v||`` per *bucket*
as truncated normals on [0, 1] (Appendix A.2) and forms the norm-weighted
mixture CDF ``F(r) = sum_n gamma_n F_n(r)`` with
``gamma_n = ||v_n||^2 / sum ||v_n||^2`` (Sec. 3.4) for the
expected-variance objective, or a pooled single fit for the
expected-*normalized*-variance ("-N") objective.

Everything here is closed-form in (Phi, phi), so processors can update
their quantization grids in parallel from a handful of scalars — this is
the "efficiently computing sufficient statistics of a parametric
distribution" part of Algorithm 1.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtri

_SQRT2PI = 2.5066282746310002
_MIN_SIGMA = 1e-4  # PDF/CDF conditioning floor (paper App. K notes this)


def _phi(z):
    return jnp.exp(-0.5 * z * z) / _SQRT2PI


def _Phi(z):
    z = jnp.asarray(z, jnp.float32)
    return 0.5 * (1.0 + jax.lax.erf(z / jnp.sqrt(2.0).astype(z.dtype)))


class TruncNormStats(NamedTuple):
    """A mixture of truncated normals on [0, 1].

    Fields are vectors over mixture components (buckets, possibly
    subsampled): location ``mu``, scale ``sigma`` of the *parent* normal,
    and mixture weight ``gamma`` (sums to 1).
    """

    mu: jnp.ndarray
    sigma: jnp.ndarray
    gamma: jnp.ndarray

    @property
    def n_components(self) -> int:
        return self.mu.shape[0]


def _z(stats: TruncNormStats, x):
    x = jnp.asarray(x)
    return (x[..., None] - stats.mu) / stats.sigma


def _normalizer(stats: TruncNormStats):
    """Phi((1-mu)/sig) - Phi((0-mu)/sig), clamped away from zero."""
    hi = _Phi((1.0 - stats.mu) / stats.sigma)
    lo = _Phi((0.0 - stats.mu) / stats.sigma)
    return jnp.maximum(hi - lo, 1e-12), lo


def mixture_pdf(stats: TruncNormStats, x) -> jnp.ndarray:
    """p(x) = sum_n gamma_n p_n(x) on [0, 1]."""
    Z, _ = _normalizer(stats)
    p = _phi(_z(stats, x)) / (stats.sigma * Z)
    inside = (jnp.asarray(x)[..., None] >= 0.0) & (jnp.asarray(x)[..., None] <= 1.0)
    p = jnp.where(inside, p, 0.0)
    return jnp.sum(stats.gamma * p, axis=-1)


def mixture_cdf(stats: TruncNormStats, x) -> jnp.ndarray:
    """F(x) = sum_n gamma_n F_n(x); F(x<=0)=0, F(x>=1)=1."""
    Z, lo = _normalizer(stats)
    F = (_Phi(_z(stats, x)) - lo) / Z
    F = jnp.clip(F, 0.0, 1.0)
    return jnp.sum(stats.gamma * F, axis=-1)


def _component_cdf(stats: TruncNormStats, x):
    Z, lo = _normalizer(stats)
    return jnp.clip((_Phi(_z(stats, x)) - lo) / Z, 0.0, 1.0)


def _component_pdf(stats: TruncNormStats, x):
    Z, _ = _normalizer(stats)
    p = _phi(_z(stats, x)) / (stats.sigma * Z)
    return p


def partial_moment0(stats: TruncNormStats, a, c) -> jnp.ndarray:
    """int_a^c dF(r) = F(c) - F(a)."""
    return mixture_cdf(stats, c) - mixture_cdf(stats, a)


def partial_moment1(stats: TruncNormStats, a, c) -> jnp.ndarray:
    """int_a^c r dF(r), closed form per component:
    mu (F(c)-F(a)) - sigma^2 (p(c)-p(a))  (paper App. B.1)."""
    Fc, Fa = _component_cdf(stats, c), _component_cdf(stats, a)
    pc, pa = _component_pdf(stats, c), _component_pdf(stats, a)
    m1 = stats.mu * (Fc - Fa) - stats.sigma ** 2 * (pc - pa)
    return jnp.sum(stats.gamma * m1, axis=-1)


def partial_moment2(stats: TruncNormStats, a, c) -> jnp.ndarray:
    """int_a^c r^2 dF(r):
    mu*m1 + sigma^2 (F(c)-F(a)) - sigma^2 (c p(c) - a p(a))."""
    a_, c_ = jnp.asarray(a), jnp.asarray(c)
    Fc, Fa = _component_cdf(stats, c), _component_cdf(stats, a)
    pc, pa = _component_pdf(stats, c), _component_pdf(stats, a)
    m1 = stats.mu * (Fc - Fa) - stats.sigma ** 2 * (pc - pa)
    m2 = stats.mu * m1 + stats.sigma ** 2 * (Fc - Fa) - stats.sigma ** 2 * (
        c_[..., None] * pc - a_[..., None] * pa
    )
    return jnp.sum(stats.gamma * m2, axis=-1)


def mixture_inverse_cdf(stats: TruncNormStats, y, iters: int = 50) -> jnp.ndarray:
    """F^{-1}(y) by bisection on [0, 1] (mixture CDF has no closed inverse).

    For a single component this agrees with the closed form
    sigma * ndtri(ybar) + mu (App. A.2); tested against it.
    """
    y = jnp.asarray(y)
    lo = jnp.zeros_like(y)
    hi = jnp.ones_like(y)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        below = mixture_cdf(stats, mid) < y
        return jnp.where(below, mid, lo), jnp.where(below, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


def single_trunc_norm_inverse_cdf(mu, sigma, y):
    """Closed-form inverse for one truncated normal (App. A.2, Eq. 18)."""
    Phi_a = _Phi((0.0 - mu) / sigma)
    Phi_b = _Phi((1.0 - mu) / sigma)
    ybar = (Phi_b - Phi_a) * y + Phi_a
    return sigma * ndtri(jnp.clip(ybar, 1e-12, 1.0 - 1e-12)) + mu


def expected_variance(stats: TruncNormStats, levels: jnp.ndarray) -> jnp.ndarray:
    """Psi(l) = sum_j int_{l_j}^{l_{j+1}} (l_{j+1}-r)(r-l_j) dF(r)  (Eq. 3).

    With the norm^2-weighted mixture this is the expected-variance
    objective of Sec. 3.4 (up to the constant sum ||v_n||^2); with a
    pooled/uniform-weight fit it is the expected normalized variance.
    """
    a = levels[:-1]
    c = levels[1:]
    m0 = partial_moment0(stats, a, c)
    m1 = partial_moment1(stats, a, c)
    m2 = partial_moment2(stats, a, c)
    # (c - r)(r - a) = -r^2 + (a + c) r - a c
    seg = -m2 + (a + c) * m1 - a * c * m0
    return jnp.sum(seg)


def stats_from_moments(
    mu: jnp.ndarray,
    var: jnp.ndarray,
    bucket_norms: jnp.ndarray,
    *,
    weighted: bool = True,
    max_components: int = 64,
) -> TruncNormStats:
    """Mixture from per-bucket first/second moments of |r|.

    This is the cheap half of the fitting path: the fused
    ``bucket_stats`` kernel emits (norm, mean_r, var_r) in one HBM sweep
    and this function turns them into the (subsampled, re-weighted)
    ``TruncNormStats`` the level updates consume.
    """
    sigma = jnp.maximum(jnp.sqrt(var), _MIN_SIGMA)

    nb = mu.shape[0]
    if nb > max_components:
        stride = nb // max_components
        idx = jnp.arange(max_components) * stride
        mu, sigma, bucket_norms = mu[idx], sigma[idx], bucket_norms[idx]

    if weighted:
        w = bucket_norms ** 2
    else:
        w = jnp.ones_like(bucket_norms)
    gamma = w / jnp.maximum(jnp.sum(w), 1e-30)
    return TruncNormStats(mu=mu, sigma=sigma, gamma=gamma)


def fit_bucket_stats(
    r: jnp.ndarray,
    bucket_norms: jnp.ndarray,
    *,
    weighted: bool = True,
    max_components: int = 64,
    mask: jnp.ndarray | None = None,
) -> TruncNormStats:
    """Fit per-bucket (mu, sigma) of normalized magnitudes.

    Args:
      r: (num_buckets, bucket_size) normalized magnitudes in [0, 1].
      bucket_norms: (num_buckets,) the Lq norms used to normalize.
      weighted: True -> gamma_n ∝ ||v_n||^2 (ALQ/AMQ, Sec 3.4);
                False -> uniform gamma (ALQ-N/AMQ-N).
      max_components: strided subsample of buckets to keep the update
        cheap (paper App. K uses 20–350 samples).
      mask: optional (num_buckets, bucket_size) validity mask (padding).
    """
    if mask is None:
        mu = jnp.mean(r, axis=1)
        var = jnp.var(r, axis=1)
    else:
        cnt = jnp.maximum(jnp.sum(mask, axis=1), 1.0)
        mu = jnp.sum(r * mask, axis=1) / cnt
        var = jnp.sum(mask * (r - mu[:, None]) ** 2, axis=1) / cnt
    return stats_from_moments(mu, var, bucket_norms, weighted=weighted,
                              max_components=max_components)


def merge_stats(stats: TruncNormStats, axis_name) -> TruncNormStats:
    """Combine sufficient statistics across data-parallel workers.

    Each worker contributes its mixture components; weights are
    renormalized globally.  Implemented as an all_gather of the (tiny)
    component vectors — this is the only extra communication the adaptive
    methods add (Algorithm 1, line 4).
    """
    mu = jax.lax.all_gather(stats.mu, axis_name, tiled=True)
    sigma = jax.lax.all_gather(stats.sigma, axis_name, tiled=True)
    gamma = jax.lax.all_gather(stats.gamma, axis_name, tiled=True)
    gamma = gamma / jnp.maximum(jnp.sum(gamma), 1e-30)
    return TruncNormStats(mu=mu, sigma=sigma, gamma=gamma)

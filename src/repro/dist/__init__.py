"""Distributed communication engine: quantized collectives + FSDP.

``sync``   ENCODE -> collective -> DECODE (Algorithm 1, lines 6-9) in two
           bit-packed wire modes, plus the sufficient-statistics gather
           and the schedule-gated level update.
``fsdp``   Flat-parameter substrate: per-slot flatten metadata, chunk
           planning, and the all-gather forward / quantized
           reduce-scatter backward used by big-arch configs.
"""
from . import fsdp, sync  # noqa: F401
from .sync import (  # noqa: F401
    SyncMetrics,
    gather_stats,
    maybe_update_levels,
    quantized_allreduce,
)

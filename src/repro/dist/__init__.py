"""Distributed communication engine: quantized collectives + FSDP.

``sync``      ENCODE -> collective -> DECODE (Algorithm 1, lines 6-9) in
              two packed wire modes, plus the sufficient-statistics
              gather and the schedule-gated level update.
``fsdp``      Flat-parameter substrate: per-slot flatten metadata, chunk
              planning, and the all-gather forward / quantized
              reduce-scatter backward used by big-arch configs.
``transport`` Injectable collective transport the wire modes run on —
              mesh axes in production, vmap axes (plus payload
              drop/weighting) for the ``repro.sim`` cluster simulator.

The payload layout itself lives in ``repro.core.codec``; its public API
is re-exported here because the codec IS the wire contract of this
package.
"""
from . import faults, fsdp, sync, transport  # noqa: F401
from repro.core.codec import (  # noqa: F401
    GradientCodec,
    MixedWidthCodec,
    UniformCodec,
    WirePayload,
    WirePlan,
    assign_mixed_widths,
    codec_for_scheme,
    make_codec,
    mixed_widths_from_gradient,
    requant_codec,
)
from .sync import (  # noqa: F401
    SyncMetrics,
    gather_stats,
    maybe_update_levels,
    quantized_allreduce,
)
from .faults import (  # noqa: F401
    FaultModel,
    FaultyTransport,
    faulty,
)
from .transport import (  # noqa: F401
    MaskedTransport,
    MeshTransport,
    Transport,
    make_transport,
)

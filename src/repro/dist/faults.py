"""Fault injection for the quantized collective wire.

``FaultModel`` is the declarative fault configuration shared by the
transport wrapper here and the cluster simulator's crash/rejoin model
(``sim.cluster``): word-level bit corruption, whole-payload drop and
delivery delay on the wire, and the per-worker crash/rejoin Markov
chain the simulator steps between rounds.

``FaultyTransport`` wraps any ``dist.transport.Transport`` and injects
faults into the GATHERED uint32 wire words — after the collective, on
the replicated (M, ...) view every worker holds — so the *real*
ENCODE -> collective -> DECODE path of ``dist.sync`` runs under faults
with no wire-mode changes.  Injection is deterministic in
``(model.seed, step, worker-row, leaf)``: every worker derives the same
corruption from the same replicated key, which keeps aggregates
replicated (the corruption is "sender-side" — all receivers see the
same corrupted bytes), keeps runs reproducible, and follows the same
seeding discipline as ``sim.cluster.sample_step``.

What a fault does to the step:

* a *bit flip* corrupts one bit of one packed word.  Without
  ``integrity=`` plans it silently decodes to a wrong gradient (that is
  the point — the brittleness being tested); with integrity on,
  ``decode_checked`` flags the bucket and ``dist.sync`` excludes it.
* a *drop* zeroes a worker's whole payload row.  An all-zero row fails
  every bucket checksum (``packing._CSUM_OFFSET``), so integrity-on
  sync excludes the worker exactly like a ``MaskedTransport`` mask.
* a *delay* makes the payload miss the step's aggregation window: on
  the wire it acts like a drop for THIS step, and the cluster cost
  model additionally bills ``delay_ms`` to the round.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .transport import Transport

# domain-separation constants for the per-step fault key
_FOLD_STEP = 0xFA17
_FOLD_DROP = 0xD209
_FOLD_DELAY = 0xDE1A


def _check_prob(name: str, p) -> None:
    vals = p if isinstance(p, tuple) else (p,)
    bad = [float(v) for v in vals if not 0.0 <= float(v) <= 1.0]
    if bad:
        raise ValueError(f"{name} must be in [0, 1], got {bad}")


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Declarative fault configuration (all probabilities per step).

    ``flip_prob`` is the per-WORD bit-flip probability on gathered wire
    words — a float, or a per-worker tuple to target specific workers
    (e.g. ``(0.0, 0.0, 1.0, 0.0)`` corrupts only worker 2's payload).
    ``drop_prob`` / ``delay_prob`` drop or delay whole per-worker
    payloads; a delayed payload misses the step (drop semantics on the
    wire) and bills ``delay_ms`` in the cluster cost model.
    ``crash_prob`` / ``rejoin_prob`` parameterize the per-worker
    up/down Markov chain stepped by ``sim.cluster`` — a crashed worker
    is absent for whole steps and rejoins with a stale payload.
    """

    flip_prob: float | tuple = 0.0
    drop_prob: float = 0.0
    delay_prob: float = 0.0
    delay_ms: float = 5.0
    crash_prob: float = 0.0
    rejoin_prob: float = 0.5
    seed: int = 0

    def __post_init__(self):
        _check_prob("flip_prob", self.flip_prob)
        for f in ("drop_prob", "delay_prob", "crash_prob", "rejoin_prob"):
            _check_prob(f, getattr(self, f))
        if self.delay_ms < 0:
            raise ValueError(f"delay_ms must be >= 0, got {self.delay_ms}")

    @property
    def any_wire_faults(self) -> bool:
        flips = (self.flip_prob if isinstance(self.flip_prob, tuple)
                 else (self.flip_prob,))
        return (any(float(p) > 0 for p in flips)
                or self.drop_prob > 0 or self.delay_prob > 0)

    def flip_probs(self, M: int) -> jnp.ndarray:
        """(M,) per-worker word-corruption probabilities."""
        if isinstance(self.flip_prob, tuple):
            if len(self.flip_prob) != M:
                raise ValueError(
                    f"flip_prob tuple has {len(self.flip_prob)} entries "
                    f"for {M} workers")
            return jnp.asarray(self.flip_prob, jnp.float32)
        return jnp.full((M,), float(self.flip_prob), jnp.float32)

    def key_for_step(self, step) -> jax.Array:
        """The replicated per-step fault key: (seed, step) -> key, same
        discipline as the cluster sampler (worker distinction comes from
        the row axis of the sampled masks, not from per-worker keys)."""
        return jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), _FOLD_STEP),
            step)

    def delayed_workers(self, step, M: int) -> jnp.ndarray:
        """(M,) bool: the step's delay draws.  Same key and draw as
        ``FaultyTransport.drop_mask``'s delay half, so the host-side
        cost model bills ``delay_ms`` for exactly the payloads the wire
        treated as late."""
        kl = jax.random.fold_in(self.key_for_step(step), _FOLD_DELAY)
        return jax.random.uniform(kl, (M,)) < jnp.float32(self.delay_prob)


class FaultyTransport(Transport):
    """Transport wrapper injecting wire faults into gathered payloads.

    Wraps an inner transport (mesh, masked, ...) and corrupts the
    uint32 rows coming out of ``all_gather`` / ``all_to_all``:
    per-word bit flips, then whole-row zeroing for dropped/delayed
    workers.  Aggregation rules (``weights`` / ``active_vector`` /
    ``mean_workers*``) delegate to the inner transport, so dropout
    masking composes with fault injection unchanged.
    """

    def __init__(self, inner: Transport, model: FaultModel,
                 key: jax.Array):
        super().__init__(inner.axes)
        self.inner = inner
        self.model = model
        self.key = key

    # ---- delegation -----------------------------------------------------

    def size(self):
        return self.inner.size()

    def rank(self):
        return self.inner.rank()

    def psum(self, x):
        return self.inner.psum(x)

    def weights(self):
        return self.inner.weights()

    def active_vector(self):
        return self.inner.active_vector()

    def mean_workers(self, stacked):
        return self.inner.mean_workers(stacked)

    def mean_workers_bucketed(self, stacked, valid, bucket_size):
        return self.inner.mean_workers_bucketed(stacked, valid,
                                                bucket_size)

    def mean_psum(self, x):
        # fp32 side-band values (stats merges, fp32 mode) are not wire
        # payloads; they pass through un-faulted.
        return self.inner.mean_psum(x)

    # ---- fault injection ------------------------------------------------

    def drop_mask(self) -> jnp.ndarray:
        """(M,) bool: workers whose payload misses this step (dropped
        or delayed past the aggregation window).  Shared across payload
        leaves so a worker loses its WHOLE payload, not one leaf."""
        M = self.size()
        kd = jax.random.fold_in(self.key, _FOLD_DROP)
        dropped = (jax.random.uniform(kd, (M,))
                   < jnp.float32(self.model.drop_prob))
        kl = jax.random.fold_in(self.key, _FOLD_DELAY)
        delayed = (jax.random.uniform(kl, (M,))
                   < jnp.float32(self.model.delay_prob))
        return dropped | delayed

    def _inject(self, rows: jnp.ndarray) -> jnp.ndarray:
        """Corrupt gathered uint32 rows: (M, ...) -> (M, ...)."""
        if rows.dtype != jnp.uint32:
            return rows
        M = rows.shape[0]
        bcast = (M,) + (1,) * (rows.ndim - 1)
        # leaf distinction: fold in the trailing word count (a static
        # layout fact), never a mutable counter — retrace-safe and
        # identical on every worker.
        leaf_key = jax.random.fold_in(self.key, rows.shape[-1])
        ku, kb = jax.random.split(leaf_key)
        u = jax.random.uniform(ku, rows.shape)
        flip = u < self.model.flip_probs(M).reshape(bcast)
        bit = jax.random.randint(kb, rows.shape, 0, 32,
                                 jnp.int32).astype(jnp.uint32)
        rows = jnp.where(flip, rows ^ (jnp.uint32(1) << bit), rows)
        return jnp.where(self.drop_mask().reshape(bcast),
                         jnp.uint32(0), rows)

    def all_gather(self, x):
        return self._inject(self.inner.all_gather(x))

    def all_to_all(self, x):
        return self._inject(self.inner.all_to_all(x))


def faulty(transport: Transport, model: FaultModel | None,
           step) -> Transport:
    """Wrap ``transport`` in the model's wire faults for one step
    (identity when the model is absent or injects nothing)."""
    if model is None or not model.any_wire_faults:
        return transport
    return FaultyTransport(transport, model, model.key_for_step(step))

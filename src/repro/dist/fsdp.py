"""Flat-parameter FSDP substrate.

Parameters of one layer slot are stored as ONE flat, zero-padded fp32
vector sharded over the data axes.  The forward materializes a slot with
a tiled ``all_gather``; the backward of that gather is a *quantized
reduce-scatter* (``custom_vjp``): each worker ENCODEs its local cotangent
through the configured ``GradientCodec`` and ships each peer only that
peer's shard as a packed ``WirePayload`` — so FSDP training moves
``b``-bit gradients in BOTH directions of the wire instead of fp32.

Layout invariants (enforced by ``padded_flat_len`` / ``chunk_plan``):

  padded length  Lp = nb_p * bucket_size
  nb_p % (M * k) == 0

so every shard holds whole buckets (the encode never straddles a shard
boundary) and the backward can run in ``k`` rounds — round c covers
slice ``[c*ppr, (c+1)*ppr)`` of every shard's buckets — letting the
encode of round c+1 overlap the all-to-all of round c.  (A
``MixedWidthCodec`` backward runs in one round: its per-bucket layout is
planned over the whole shard.)

Zero-padding is an exact fixed point of ENCODE/DECODE (sign 0 -> code 0),
so padded master parameters never drift.

The payload is moved generically (``jax.tree.map(transport.all_to_all,
payload)``), so the backward carries whatever the codec lays out — for
an ``EntropyCodec`` each round's chunks travel as per-bucket
canonical-Huffman runs with the coded-length word in the bucket header
(capacity-static arrays, so the ``k``-round overlap and the all-to-all
shapes are unchanged), decoding bit-exact against the uniform codec
(``tests/test_entropy_codec.py``).

``make_gather(algorithm=...)`` composes the backward with a stateful
``repro.compress`` algorithm: the reduce-scatter encodes
``cotangent + residual`` and the new error-feedback residual comes back
as the cotangent of an explicit ``residual`` input (see
docs/compression.md).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import GradientCodec, codec_for_scheme
from repro.core.schemes import QuantScheme
from repro.dist import transport as transport_lib

# ---------------------------------------------------------------------------
# flatten metadata
# ---------------------------------------------------------------------------

def flatten_meta(specs: dict, prefix: tuple = ()) -> list:
    """Param-spec tree -> deterministic flat layout.

    ``specs`` leaves are ``(shape, init_code)`` pairs (see
    ``models.transformer.slot_param_specs``).  Returns a list of
    ``(path, shape, init_code)`` in sorted-name order at every level, so
    the layout is reproducible from the spec alone.
    """
    meta = []
    for name in sorted(specs):
        sub = specs[name]
        if isinstance(sub, dict):
            meta.extend(flatten_meta(sub, prefix + (name,)))
        else:
            shape, code = sub
            meta.append((prefix + (name,), tuple(shape), code))
    return meta


def flat_size(meta: list) -> int:
    return sum(math.prod(shape) for _, shape, _ in meta)


def chunk_plan(n: int, bucket_size: int, M: int) -> tuple[int, int]:
    """(k, nb_padded) for an n-element flat vector on M workers.

    Picks the deepest chunking k in {8, 4, 2, 1} that still gives every
    worker at least one bucket per round, then pads the bucket count to a
    multiple of ``M * k`` so rounds and shards tile exactly.
    """
    nb = -(-n // bucket_size)
    k = 1
    for cand in (8, 4, 2):
        if cand * M <= nb:
            k = cand
            break
    group = M * k
    return k, -(-nb // group) * group


def padded_flat_len(meta: list, bucket_size: int, world: int,
                    shards: int | None = None) -> int:
    """Padded flat length: bucket-, round-, and shard-divisible."""
    m = world if shards is None else math.lcm(world, shards)
    _, nb_p = chunk_plan(flat_size(meta), bucket_size, m)
    return nb_p * bucket_size


def unflatten(flat: jnp.ndarray, meta: list, dtype) -> dict:
    """Flat (padded) vector -> nested param dict per ``meta``'s layout."""
    tree: dict = {}
    off = 0
    for path, shape, _ in meta:
        size = math.prod(shape)
        leaf = jax.lax.slice_in_dim(flat, off, off + size)
        node = tree
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = leaf.reshape(shape).astype(dtype)
        off += size
    return tree


# ---------------------------------------------------------------------------
# quantized reduce-scatter (the gather's backward)
# ---------------------------------------------------------------------------

def _rounds_for(shard_nb: int) -> int:
    # The backward only sees the (already padded) cotangent shape, so the
    # round count is re-derived here instead of threaded from chunk_plan.
    # Correctness rests solely on the divisibility check below; k may
    # legitimately exceed chunk_plan's k when the padding allows it.
    for cand in (8, 4, 2):
        if shard_nb % cand == 0 and shard_nb > cand:
            return cand
    return 1


def _quantized_reduce_scatter(g, levels, key, *, axes,
                              codec: GradientCodec, use_pallas,
                              residual=None):
    """(Lp,) per-worker cotangent -> (Lp/M,) shard of the worker MEAN.

    Runs in rounds over sub-slices of every shard so the ENCODE of round
    c+1 is independent of (and can overlap) the all-to-all of round c.
    The wire carries the codec's packed payload (words + norm words) —
    the bandwidth-optimal reduce-scatter volume at the codec's widths.

    ``residual`` enables error feedback (``repro.compress``) on this
    backward: the (Lp,)-shaped memory is added to the cotangent before
    ENCODE, and the new residual ``inp - Q(inp)`` is assembled from the
    decode of the worker's OWN sharded payloads — zero additional wire
    bytes.  Returns ``(shard_mean, new_residual)`` in that case.
    """
    transport = transport_lib.make_transport(axes)
    M = transport.size()
    # worker-distinct rounding randomness even when the caller passes a
    # replicated key: correlated rounding across workers would forfeit
    # the 1/M variance averaging of the mean
    key = jax.random.fold_in(key, transport.rank())
    if residual is not None:
        g = g + residual
    bs = codec.bucket_size
    nb = g.shape[0] // bs
    shard_nb = nb // M
    # mixed-width layouts are planned per whole shard: one round
    k = _rounds_for(shard_nb) if codec.chunkable else 1
    ppr = shard_nb // k  # buckets per shard per round
    gb = g.reshape(M, shard_nb, bs)

    pieces, own_rounds = [], []
    for c in range(k):
        sub = jax.lax.slice_in_dim(gb, c * ppr, (c + 1) * ppr, axis=1)
        vb = sub.reshape(M * ppr, bs)
        plan = codec.plan_buckets(M * ppr, shards=M)
        payload = codec.encode(vb, levels, jax.random.fold_in(key, c),
                               plan, use_pallas=use_pallas)
        if M == 1:
            payload = jax.tree.map(lambda a: a[None], payload)
        if residual is not None:
            # own round trip: segment j of the own payload is shard j's
            # round-c slice -> (M, ppr*bs), row j for shard j
            own_rounds.append(codec.decode(
                payload, levels, plan, shard=None, use_pallas=use_pallas))
        received = jax.tree.map(transport.all_to_all, payload)
        vals = codec.decode(received, levels, plan,
                            shard=transport.rank(),
                            use_pallas=use_pallas)     # (M, ppr*bs)
        pieces.append(vals.mean(0))
    shard_mean = jnp.concatenate(pieces)
    if residual is None:
        return shard_mean
    own = jnp.concatenate(
        [r.reshape(M, ppr, bs) for r in own_rounds], axis=1)  # (M,snb,bs)
    return shard_mean, g - own.reshape(-1)


def _float0_zeros(x):
    """Cotangent for a non-differentiable (integer / key) input."""
    return np.zeros(jnp.shape(x), jax.dtypes.float0)


def _check_not_vmapped(shard, axes):
    """Fail fast on the known jax-0.4.37 quirk: batching the gather's
    ``custom_vjp`` backward (an ``all_to_all`` reduce-scatter) under a
    PLAIN ``jax.vmap`` axis mis-shapes the collective's batching rule
    (``mul got incompatible shapes for broadcasting``).  The shard_map
    path is unaffected — and is the production path — so point there
    instead of letting the broadcast error surface layers deeper.
    """
    from jax.interpreters import batching
    x = shard
    batched = False
    while isinstance(x, jax.core.Tracer):
        if isinstance(x, batching.BatchTracer):
            batched = True
            break
        # unwrap one autodiff/batching level (grad wraps the vmap
        # tracer in a JVPTracer, so one isinstance is not enough)
        if hasattr(x, "primal"):
            x = x.primal
        elif hasattr(x, "val"):
            x = x.val
        else:
            break
    if batched:
        raise NotImplementedError(
            "make_gather cannot run under a plain jax.vmap axis on this "
            "jax pin (0.4.37): vmap-batching the custom_vjp backward's "
            "all_to_all reduce-scatter hits a known custom_vjp x "
            "all_to_all batching quirk.  Run the gather inside "
            "jax.shard_map over mesh axes "
            f"{tuple(axes)!r} instead (see tests/test_fsdp_quantized.py "
            "for the harness), or call _quantized_reduce_scatter "
            "directly — plain functions vmap fine.")


def make_gather(data_axes, scheme: QuantScheme, fsdp_sync: str = "quantized",
                *, use_pallas: bool = False,
                codec: GradientCodec | None = None,
                algorithm=None, guard_vmap: bool = True):
    """Returns ``gather(shard, levels, key) -> full`` for one flat slot.

    Forward: tiled all_gather of the param shard over ``data_axes``.
    Backward: reduce-scatter of the cotangent to the worker MEAN —
    quantized (the codec's packed payload on the wire) when
    ``fsdp_sync == 'quantized'`` and the scheme quantizes, else fp32
    ``psum_scatter``.  ``codec`` defaults to the scheme's uniform codec;
    a ``MixedWidthCodec`` moves per-bucket mixed widths instead, and a
    ``SparseCodec`` top-k index+value payloads.

    ``algorithm`` (a stateful ``repro.compress`` algorithm, e.g. error
    feedback) changes the signature to ``gather(shard, levels, key,
    residual) -> full``: the backward encodes ``cotangent + residual``
    through the algorithm's codec, and the NEW residual ``inp - Q(inp)``
    comes back as the cotangent of the ``residual`` input — the only
    channel a ``custom_vjp`` backward has to emit state.  Callers
    differentiate with respect to ``residual`` too and carry that
    "gradient" as next step's memory (see ``tests/test_compress.py``).
    The 4-arg contract survives the ``fsdp_sync='fp32'`` toggle (the
    residual flushes into the lossless mean and zeroes); algorithm
    ``warmup_steps`` raises here — the gather has no step counter to
    gate on.

    ``use_pallas`` defaults to False: on CPU the interpret-mode kernels
    materialize every grid block (see launch/dryrun.py); flip it on for
    real-TPU runs.  ``guard_vmap=False`` disables the fail-fast check
    for the known plain-vmap batching quirk (kept only so the pinning
    xfail test can exercise the raw behavior).
    """
    axes = tuple(data_axes)
    quantized = fsdp_sync == "quantized" and scheme.quantized
    if algorithm is not None:
        codec = algorithm.codec
        if algorithm.stateful and algorithm.warmup_steps:
            raise ValueError(
                "warmup_steps is not supported on the gather-level EF "
                "path: the gather carries no step counter, so the gate "
                "cannot be evaluated here.  Gate the residual in the "
                "training loop instead (inject zeros until warmup ends).")
        if not algorithm.stateful:
            algorithm = None  # 'plain': the stateless 3-arg gather
    if codec is None:
        codec = codec_for_scheme(scheme)

    def gather(shard, levels, key):
        if guard_vmap:
            _check_not_vmapped(shard, axes)

        @jax.custom_vjp
        def f(s, lv, k):
            return jax.lax.all_gather(s, axes, tiled=True)

        def fwd(s, lv, k):
            return jax.lax.all_gather(s, axes, tiled=True), (lv, k)

        def bwd(res, g):
            lv, k = res
            if quantized:
                ds = _quantized_reduce_scatter(
                    g, lv, k, axes=axes, codec=codec,
                    use_pallas=use_pallas)
            else:
                M = transport_lib.axes_size(axes)
                ds = jax.lax.psum_scatter(
                    g, axes, scatter_dimension=0, tiled=True) / M
            return ds, jnp.zeros_like(lv), _float0_zeros(k)

        f.defvjp(fwd, bwd)
        return f(shard, levels, key)

    def gather_ef(shard, levels, key, residual):
        if guard_vmap:
            _check_not_vmapped(shard, axes)

        @jax.custom_vjp
        def f(s, lv, k, r):
            return jax.lax.all_gather(s, axes, tiled=True)

        def fwd(s, lv, k, r):
            return jax.lax.all_gather(s, axes, tiled=True), (lv, k, r)

        def bwd(res, g):
            lv, k, r = res
            if quantized:
                ds, new_r = _quantized_reduce_scatter(
                    g, lv, k, axes=axes, codec=codec,
                    use_pallas=use_pallas, residual=r)
            else:
                # fp32 toggle: same 4-arg contract, lossless sync ->
                # the residual is flushed into the mean and zeroed
                M = transport_lib.axes_size(axes)
                ds = jax.lax.psum_scatter(
                    g + r, axes, scatter_dimension=0, tiled=True) / M
                new_r = jnp.zeros_like(r)
            return ds, jnp.zeros_like(lv), _float0_zeros(k), new_r

        f.defvjp(fwd, bwd)
        return f(shard, levels, key, residual)

    return gather_ef if algorithm is not None else gather

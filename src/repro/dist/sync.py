"""Quantized gradient synchronization (Algorithm 1, lines 2-9).

Everything here runs INSIDE ``shard_map``: collectives are expressed over
named mesh axes (``axes``), and what travels over the interconnect is the
bit-packed wire format of ``core/packing.py`` — ``ceil(n*b/32)`` uint32
words plus one fp32 norm per bucket — never dequantized fp32.

Wire modes
----------
``all_gather``  Every worker ENCODEs its local gradient (fused Pallas
    kernel), packs the signed level indices into a dense word stream, and
    all-gathers (words, norms).  One decode+average pass over the M*nb
    gathered buckets yields the aggregate; since every worker decodes the
    same gathered bytes, the result is bit-identical everywhere (the
    paper's broadcast-all scheme, Sec. 5).

``two_phase``   The reduce direction is compressed with the scheme's own
    grid and moved as an all-to-all (a true quantized reduce-scatter:
    each worker ships each peer only that peer's shard).  Each worker
    then RE-quantizes its shard of the aggregate on a fixed 8-bit
    uniform/L-inf grid — fine enough that the second rounding does not
    forfeit the 1/M variance averaging (see benchmarks/bench_twophase) —
    and the packed result is all-gathered.  Total wire is ~(b + 8/M + 9)
    bits/coord instead of the broadcast scheme's M*b.

``fp32``        Plain psum mean (SuperSGD / debugging baseline).

``gather_stats`` is the sufficient-statistics path (Algorithm 1, line 4):
one fused ``bucket_stats`` sweep, strided subsampling to
``max_stat_components``, and a tiny cross-worker mixture merge.
``maybe_update_levels`` wraps it in ``lax.cond`` so the ~10k non-update
steps pay nothing.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.levels import uniform_levels
from repro.core.quantize import NORM_LINF, pad_to_buckets
from repro.core.schemes import QuantScheme, SchemeState
from repro.core.stats import TruncNormStats, merge_stats, stats_from_moments
from repro.dist import transport as transport_lib
from repro.dist.transport import Transport, make_transport
from repro.kernels import ops
from repro.kernels.quantize import DEFAULT_BUCKET_TILE

# Phase-2 grid of the two_phase mode: 8-bit uniform levels under L-inf
# bucket normalization (QSGDinf at 8 bits).  L-inf spreads the aggregate's
# normalized magnitudes over [0, 1], so the 1/255 grid step stays well
# below phase-1 noise at any bucket size.
TWO_PHASE_BITS = 8


class SyncMetrics(NamedTuple):
    """Per-step wire accounting, split by direction so asymmetric modes
    (two_phase: cheap reduce hop, 9-bit broadcast hop) are visible to
    cost models (``repro.sim``) instead of one aggregate number."""

    comm_bits_per_coord: jnp.ndarray       # total = reduce + broadcast
    quant_error: jnp.ndarray  # local ||Q(g) - g||^2 (own encode)
    reduce_bits_per_coord: jnp.ndarray     # toward-aggregate hop (phase 1)
    broadcast_bits_per_coord: jnp.ndarray  # from-aggregate hop (phase 2 /
    #                                        the broadcast-all gather)


# axis helpers (one implementation, in transport; fsdp imports them here)
_axes_size = transport_lib.axes_size
_axes_rank = transport_lib.axes_rank


def _bucketize(flat: jnp.ndarray, bucket_size: int,
               group: int = DEFAULT_BUCKET_TILE) -> jnp.ndarray:
    """(d,) -> (nb_p, bucket_size) zero-padded; nb_p group-aligned.

    Zero buckets are exact fixed points of ENCODE/DECODE (norm 0, code 0),
    so padding never leaks into the aggregate.
    """
    vb = pad_to_buckets(flat, bucket_size)
    nb = vb.shape[0]
    nb_p = -(-nb // group) * group
    if nb_p != nb:
        vb = jnp.concatenate(
            [vb, jnp.zeros((nb_p - nb, bucket_size), vb.dtype)])
    return vb


def _encode(vb, levels, key, norm_type, use_pallas):
    u = jax.random.uniform(key, vb.shape, jnp.float32)
    return ops.quantize_op(vb, u, levels, norm_type=norm_type,
                           use_pallas=use_pallas)


def _decode_streams(words, norms, n_per_stream, levels, use_pallas):
    """(M, W) packed words + (M, nb) norms -> (M, n_per_stream) values.

    One fused dequantize pass over all M*nb gathered buckets.
    """
    L = levels.shape[0]
    M, nb = norms.shape
    bs = n_per_stream // nb
    sym = jax.vmap(lambda w: packing.unpack_signed(w, n_per_stream, L))(words)
    vals = ops.dequantize_op(sym.reshape(M * nb, bs), norms.reshape(-1),
                             levels, use_pallas=use_pallas)
    return vals.reshape(M, n_per_stream)


# ---------------------------------------------------------------------------
# wire modes
# ---------------------------------------------------------------------------

def _allreduce_all_gather(flat, scheme, levels, key, transport, use_pallas):
    d = flat.shape[0]
    L = levels.shape[0]
    vb = _bucketize(flat, scheme.bucket_size)
    nb, bs = vb.shape
    n = nb * bs

    codes, norms = _encode(vb, levels, key, scheme.norm_type, use_pallas)
    words = packing.pack_signed(codes, L)
    nwords = packing.pack_norms(norms, scheme.norm_dtype)

    gw = transport.all_gather(words)    # (M, W) uint32
    gnw = transport.all_gather(nwords)  # (M, norm_words) uint32
    gn = jax.vmap(
        lambda w: packing.unpack_norms(w, nb, scheme.norm_dtype))(gnw)

    per_worker = _decode_streams(gw, gn, n, levels, use_pallas)
    out = transport.mean_workers(per_worker)[:d]

    own = jnp.take(per_worker, transport.rank(), axis=0)[:d]
    qerr = jnp.sum((own - flat) ** 2)
    # the single gather IS the broadcast-all hop (paper Sec. 5)
    bits = jnp.float32((words.size + nwords.size) * 32.0 / d)
    return out, SyncMetrics(bits, qerr, jnp.float32(0.0), bits)


def _allreduce_two_phase(flat, scheme, levels, key, transport, use_pallas):
    d = flat.shape[0]
    L = levels.shape[0]
    M = transport.size()
    nd = scheme.norm_dtype
    # nb_p % (M * tile) == 0: whole buckets per shard AND tile-aligned
    # encode/decode on both the full and the per-shard bucket counts.
    vb = _bucketize(flat, scheme.bucket_size, group=M * DEFAULT_BUCKET_TILE)
    nb, bs = vb.shape
    shard_nb = nb // M
    shard_n = shard_nb * bs

    # ---- phase 1: quantized reduce-scatter (scheme grid) ----
    codes, norms = _encode(vb, levels, key, scheme.norm_type, use_pallas)
    words = jnp.stack([
        packing.pack_signed(
            jax.lax.slice_in_dim(codes, j * shard_nb, (j + 1) * shard_nb), L)
        for j in range(M)])                               # (M, Ws)
    nwords = jax.vmap(lambda x: packing.pack_norms(x, nd))(
        norms.reshape(M, shard_nb))                       # (M, Wn)
    rw = transport.all_to_all(words)
    rnw = transport.all_to_all(nwords)
    rn = jax.vmap(lambda w: packing.unpack_norms(w, shard_nb, nd))(rnw)
    shard_per_worker = _decode_streams(rw, rn, shard_n, levels, use_pallas)
    shard_mean = transport.mean_workers(shard_per_worker)
    shard_mean = shard_mean.reshape(shard_nb, bs)

    # ---- phase 2: re-quantize the aggregate, broadcast compressed ----
    lv2 = uniform_levels(TWO_PHASE_BITS)
    L2 = lv2.shape[0]
    c2, n2 = _encode(shard_mean, lv2, jax.random.fold_in(key, 0x2FA5E),
                     NORM_LINF, use_pallas)
    w2 = packing.pack_signed(c2, L2)
    n2w = packing.pack_norms(n2, nd)
    gw2 = transport.all_gather(w2)      # (M, Ws2)
    gn2w = transport.all_gather(n2w)    # (M, Wn2)
    gn2 = jax.vmap(lambda w: packing.unpack_norms(w, shard_nb, nd))(gn2w)
    out = _decode_streams(gw2, gn2, shard_n, lv2, use_pallas)
    out = out.reshape(-1)[:d]

    # local decode of own phase-1 contribution for the error metric
    own = ops.dequantize_op(codes, norms, levels, use_pallas=use_pallas)
    qerr = jnp.sum((own.reshape(-1)[:d] - flat) ** 2)
    bits_reduce = jnp.float32((words.size + nwords.size) * 32.0 / d)
    bits_bcast = jnp.float32((w2.size + n2w.size) * 32.0 / d)
    return out, SyncMetrics(bits_reduce + bits_bcast, qerr,
                            bits_reduce, bits_bcast)


def quantized_allreduce(
    flat: jnp.ndarray,
    scheme: QuantScheme,
    state: SchemeState,
    key: jax.Array,
    *,
    axes=(),
    mode: str = "all_gather",
    use_pallas: bool = True,
    transport: Transport | None = None,
) -> tuple[jnp.ndarray, SyncMetrics]:
    """ENCODE -> collective -> DECODE -> average; replicated output.

    Args:
      flat: (d,) local gradient (call inside shard_map; no implicit psum).
      scheme / state: quantization method and its adaptive state (levels).
      key: PRNG key, REPLICATED across workers — worker-distinct
        randomness is derived by folding in the global rank.
      axes: named mesh axes to synchronize over (may be empty: M=1).
        The axes may equally be ``jax.vmap`` axis names — that is how
        ``repro.sim`` runs M logical workers on one host through this
        exact code path.
      mode: 'fp32' | 'all_gather' | 'two_phase'.
      transport: collective transport override (``dist.transport``);
        defaults to plain named-axis collectives over ``axes``.  The
        simulator injects a ``MaskedTransport`` here to drop per-worker
        payloads (worker dropout) without touching the wire-mode code.

    Returns (aggregate mean, SyncMetrics); the aggregate is bit-identical
    on every worker in all modes.
    """
    flat = flat.reshape(-1)
    axes = tuple(axes)
    if transport is None:
        transport = make_transport(axes)
    if mode == "fp32" or not scheme.quantized:
        out = transport.mean_psum(flat)
        return out, SyncMetrics(jnp.float32(32.0), jnp.float32(0.0),
                                jnp.float32(32.0), jnp.float32(0.0))

    levels = state.levels
    if transport.axes:
        key = jax.random.fold_in(key, transport.rank())
    if mode == "all_gather":
        return _allreduce_all_gather(flat, scheme, levels, key, transport,
                                     use_pallas)
    if mode == "two_phase":
        return _allreduce_two_phase(flat, scheme, levels, key, transport,
                                    use_pallas)
    raise ValueError(f"unknown sync mode {mode!r}")


# ---------------------------------------------------------------------------
# sufficient statistics + schedule-gated level update
# ---------------------------------------------------------------------------

def gather_stats(
    flat: jnp.ndarray,
    scheme: QuantScheme,
    *,
    axes=(),
    use_pallas: bool = True,
) -> TruncNormStats:
    """One-sweep sufficient statistics of the local gradient, merged
    across workers (Algorithm 1, line 4).

    A single fused ``bucket_stats`` pass emits per-bucket (norm, mean_r,
    var_r); only ``max_stat_components`` scalars per worker travel in the
    merge — this is the only communication the adaptive methods add.
    """
    flat = flat.reshape(-1)
    axes = tuple(axes)
    vb = _bucketize(flat, scheme.bucket_size)
    norms, mu, var = ops.bucket_stats_op(vb, norm_type=scheme.norm_type,
                                         use_pallas=use_pallas)
    # keep only fully-populated buckets: alignment padding is all-zero,
    # and a trailing partial bucket's intra-bucket zeros would bias its
    # (mu, sigma) toward 0 — drop it unless it is the only bucket
    nb_valid = max(flat.shape[0] // scheme.bucket_size, 1)
    stats = stats_from_moments(
        mu[:nb_valid], var[:nb_valid], norms[:nb_valid],
        weighted=scheme.weighted_stats,
        max_components=scheme.max_stat_components)
    if axes:
        stats = merge_stats(stats, axes)
    return stats


def maybe_update_levels(
    flat: jnp.ndarray,
    scheme: QuantScheme,
    state: SchemeState,
    do_update,
    *,
    axes=(),
    use_pallas: bool = True,
) -> SchemeState:
    """Run the scheme's level adaptation iff ``do_update`` (traced bool).

    ``lax.cond``-gated: on non-update steps neither the stats sweep nor
    the (tiny) merge collective executes — the adaptive methods' extra
    cost lands only on the paper's sparse schedule (App. K).
    """
    if not scheme.adaptive:
        return state
    flat = jax.lax.stop_gradient(flat.reshape(-1))

    def upd(s):
        stats = gather_stats(flat, scheme, axes=axes, use_pallas=use_pallas)
        return scheme.update_state(s, stats)

    return jax.lax.cond(do_update, upd, lambda s: s, state)

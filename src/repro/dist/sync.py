"""Quantized gradient synchronization (Algorithm 1, lines 2-9).

Everything here runs INSIDE ``shard_map``: collectives are expressed over
named mesh axes (``axes``), and what travels over the interconnect is a
``core.codec.WirePayload`` — dense uint32 words of packed level symbols
plus packed bucket norms — never dequantized fp32.  The payload layout
(including per-bucket mixed widths) is owned entirely by the
``GradientCodec``; this module only sequences ENCODE -> collective ->
DECODE -> average over a ``Transport``.

Wire modes
----------
``all_gather``  Every worker ENCODEs its local gradient, and the packed
    payload is all-gathered.  One fused decode+average pass over the M
    gathered streams yields the aggregate; since every worker decodes
    the same gathered bytes, the result is bit-identical everywhere (the
    paper's broadcast-all scheme, Sec. 5).

``two_phase``   The reduce direction is compressed with the scheme's own
    grid and moved as an all-to-all of the codec's *sharded* payload (a
    true quantized reduce-scatter: each worker ships each peer only that
    peer's shard).  Each worker then RE-quantizes its shard of the
    aggregate on a fixed 8-bit uniform/L-inf grid — fine enough that the
    second rounding does not forfeit the 1/M variance averaging (see
    benchmarks/bench_twophase) — and the packed result is all-gathered.
    Total wire is ~(b + 8/M + 9) bits/coord instead of the broadcast
    scheme's M*b.

``fp32``        Plain psum mean (SuperSGD / debugging baseline).

``compressed_allreduce`` wraps the same wire modes in the
``repro.compress`` algorithm hook (error-feedback residual injection
before ENCODE, residual update from the codec's own local decode after
DECODE) — the stateless ``plain`` algorithm is bit-exact with
``quantized_allreduce``.

``gather_stats`` is the sufficient-statistics path (Algorithm 1, line 4):
one fused ``bucket_stats`` sweep, strided subsampling to
``max_stat_components``, and a tiny cross-worker mixture merge.
``maybe_update_levels`` wraps it in ``lax.cond`` so the ~10k non-update
steps pay nothing.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.codec import GradientCodec, codec_for_scheme, requant_codec
from repro.core.levels import uniform_levels
from repro.core.schemes import QuantScheme, SchemeState
from repro.core.stats import TruncNormStats, merge_stats, stats_from_moments
from repro.dist.transport import Transport, make_transport
from repro.kernels import ops

# Phase-2 grid of the two_phase mode: 8-bit uniform levels under L-inf
# bucket normalization (QSGDinf at 8 bits).  L-inf spreads the aggregate's
# normalized magnitudes over [0, 1], so the 1/255 grid step stays well
# below phase-1 noise at any bucket size.
TWO_PHASE_BITS = 8


class SyncMetrics(NamedTuple):
    """Per-step wire accounting, split by direction so asymmetric modes
    (two_phase: cheap reduce hop, 9-bit broadcast hop) are visible to
    cost models (``repro.sim``) instead of one aggregate number.

    The bits/coord fields are MEASURED for variable-volume codecs
    (``WirePlan.variable``, the entropy-coded payload family): what the
    per-bucket coded-length headers say actually needs to travel, not
    the static worst-case plan.  For fixed-layout codecs measured ==
    planned, bit for bit.

    Defaulted fields are ``jnp.float32`` SCALARS, not Python floats, so
    harnesses that build shard_map out_specs from ``metric_specs()``
    see a uniform float32 metric dtype on every path (incl. the
    no-update / stateless paths that never ``_replace`` them)."""

    comm_bits_per_coord: jnp.ndarray       # total = reduce + broadcast
    quant_error: jnp.ndarray  # local ||Q(g) - g||^2 (own encode)
    reduce_bits_per_coord: jnp.ndarray     # toward-aggregate hop (phase 1)
    broadcast_bits_per_coord: jnp.ndarray  # from-aggregate hop (phase 2 /
    #                                        the broadcast-all gather)
    entropy_bits_per_coord: jnp.ndarray = jnp.float32(0.0)  # achievable
    #   entropy-coded cost of the CURRENT grid: H(L) + Pr(sym != 0) sign
    #   bits, fit at the last level update (``SchemeState
    #   .entropy_bits``); fixed-width wire bits until the first update.
    #   With the EntropyCodec this is the target the measured
    #   comm_bits_per_coord converges onto.
    residual_norm: jnp.ndarray = jnp.float32(0.0)  # ||error-feedback
    #   residual|| after this step's feedback (repro.compress); 0 for
    #   stateless algorithms.
    kept_fraction: jnp.ndarray = jnp.float32(1.0)  # coordinates on the
    #   wire / total (static; < 1 only for the sparse payload family).
    #   The EXACT shipped sparse bits/coord are comm_bits_per_coord —
    #   every WirePlan accounts indices + values + norms + alignment.
    corrupt_fraction: jnp.ndarray = jnp.float32(0.0)  # fraction of
    #   gathered (worker, bucket) wire slots that FAILED an integrity
    #   check this step and were excluded from the aggregate; always 0
    #   without ``integrity=`` plans (nothing is checked).
    excluded_workers: jnp.ndarray = jnp.float32(0.0)  # workers whose
    #   ENTIRE payload failed integrity (dropped/zeroed rows) — they
    #   aggregate exactly like a MaskedTransport-masked worker.


# ---------------------------------------------------------------------------
# wire modes
# ---------------------------------------------------------------------------

def _allreduce_all_gather(flat, codec, levels, key, transport, use_pallas):
    d = flat.shape[0]
    plan = codec.plan(d)
    vb = codec.bucketize(flat, plan)
    payload = codec.encode(vb, levels, key, plan, use_pallas=use_pallas)

    gathered = jax.tree.map(transport.all_gather, payload)   # (M, ...)
    if plan.integrity:
        # checked decode: per-(worker, bucket) validity verdicts, with
        # detected-corrupt buckets excluded from the aggregate by the
        # per-bucket renormalization rule (a fully-invalid worker
        # aggregates bit-exactly like a transport-masked one).  ``own``
        # comes from the LOCAL payload, not the gathered row — wire
        # corruption of one's own row must not poison the error-
        # feedback residual (bit-identical when the wire is clean).
        per_worker, valid = codec.decode_checked(gathered, levels, plan,
                                                 use_pallas=use_pallas)
        out = transport.mean_workers_bucketed(
            per_worker, valid, plan.bucket_size)[:d]
        own = codec.decode(payload, levels, plan,
                           use_pallas=use_pallas)[:d]
        corrupt = jnp.mean(1.0 - valid.astype(jnp.float32))
        excluded = jnp.sum(jnp.all(~valid, axis=1).astype(jnp.float32))
    else:
        per_worker = codec.decode(gathered, levels, plan,
                                  use_pallas=use_pallas)      # (M, n)
        out = transport.mean_workers(per_worker)[:d]
        own = jnp.take(per_worker, transport.rank(), axis=0)[:d]
        corrupt = jnp.float32(0.0)
        excluded = jnp.float32(0.0)
    qerr = jnp.sum((own - flat) ** 2)
    # the single gather IS the broadcast-all hop (paper Sec. 5);
    # variable-volume codecs report what their headers say this
    # worker's payload actually ships, not the static capacity
    bits = (codec.measured_bits_per_coord(payload, plan)
            if plan.variable else jnp.float32(plan.bits_per_coord))
    return out, own, SyncMetrics(bits, qerr, jnp.float32(0.0), bits,
                                 corrupt_fraction=corrupt,
                                 excluded_workers=excluded)


def _allreduce_two_phase(flat, codec, levels, key, transport, use_pallas):
    d = flat.shape[0]
    M = transport.size()
    plan = codec.plan(d, shards=M)

    # ---- phase 1: quantized reduce-scatter (scheme grid) ----
    vb = codec.bucketize(flat, plan)
    payload = codec.encode(vb, levels, key, plan, use_pallas=use_pallas)
    if M == 1:  # unsharded payload is 1-D; the wire still sees one row
        payload = jax.tree.map(lambda a: a[None], payload)
    received = jax.tree.map(transport.all_to_all, payload)
    corrupt = jnp.float32(0.0)
    excluded = jnp.float32(0.0)
    if plan.integrity:
        shard_per_worker, valid1 = codec.decode_checked(
            received, levels, plan, shard=transport.rank(),
            use_pallas=use_pallas)                           # (M, shard_n)
        shard_mean = transport.mean_workers_bucketed(
            shard_per_worker, valid1, plan.bucket_size)
        corrupt = corrupt + jnp.sum(1.0 - valid1.astype(jnp.float32))
        excluded = jnp.sum(jnp.all(~valid1, axis=1).astype(jnp.float32))
    else:
        shard_per_worker = codec.decode(received, levels, plan,
                                        shard=transport.rank(),
                                        use_pallas=use_pallas)
        shard_mean = transport.mean_workers(shard_per_worker)
    shard_mean = shard_mean.reshape(plan.shard_nb, plan.bucket_size)

    # ---- phase 2: re-quantize the aggregate, broadcast compressed ----
    codec2 = requant_codec(codec, TWO_PHASE_BITS)
    lv2 = uniform_levels(TWO_PHASE_BITS)
    plan2 = codec2.plan_buckets(plan.shard_nb)
    pay2 = codec2.encode(shard_mean, lv2,
                         jax.random.fold_in(key, 0x2FA5E), plan2,
                         use_pallas=use_pallas)
    g2 = jax.tree.map(transport.all_gather, pay2)
    if plan2.integrity:
        # phase 2 carries each shard of the aggregate exactly once —
        # no redundancy to renormalize over, so a detected-corrupt
        # phase-2 bucket zero-fills (skips the coordinate this step)
        out, valid2 = codec2.decode_checked(g2, lv2, plan2,
                                            use_pallas=use_pallas)
        # where, not multiply: corrupt buckets can decode to NaN and
        # NaN * 0 would leak into the skipped coordinates
        out = jnp.where(valid2[..., None],
                        out.reshape(M, plan2.nb, plan2.bucket_size), 0.0)
        corrupt = corrupt + jnp.sum(1.0 - valid2.astype(jnp.float32))
        denom = jnp.float32(valid1.size + valid2.size)
        corrupt = corrupt / denom
    else:
        out = codec2.decode(g2, lv2, plan2, use_pallas=use_pallas)
    out = out.reshape(-1)[:d]

    # own phase-1 payload, decoded shard by shard, for the error metric
    # (and for the compress layer's residual feedback)
    own = codec.decode(payload, levels, plan, shard=None,
                       use_pallas=use_pallas).reshape(-1)[:d]
    qerr = jnp.sum((own - flat) ** 2)
    bits_reduce = (codec.measured_bits_per_coord(payload, plan)
                   if plan.variable
                   else jnp.float32(plan.bits_per_coord))
    bits_bcast = jnp.float32(
        32.0 * (plan2.code_words + plan2.norm_words) / d)
    return out, own, SyncMetrics(bits_reduce + bits_bcast, qerr,
                                 bits_reduce, bits_bcast,
                                 corrupt_fraction=corrupt,
                                 excluded_workers=excluded)


def quantized_allreduce(
    flat: jnp.ndarray,
    scheme: QuantScheme,
    state: SchemeState,
    key: jax.Array,
    *,
    axes=(),
    mode: str = "all_gather",
    use_pallas: bool = True,
    transport: Transport | None = None,
    codec: GradientCodec | None = None,
    return_own: bool = False,
) -> tuple:
    """ENCODE -> collective -> DECODE -> average; replicated output.

    Args:
      flat: (d,) local gradient (call inside shard_map; no implicit psum).
      scheme / state: quantization method and its adaptive state (levels).
      key: PRNG key, REPLICATED across workers — worker-distinct
        randomness is derived by folding in the global rank.
      axes: named mesh axes to synchronize over (may be empty: M=1).
        The axes may equally be ``jax.vmap`` axis names — that is how
        ``repro.sim`` runs M logical workers on one host through this
        exact code path.
      mode: 'fp32' | 'all_gather' | 'two_phase'.
      transport: collective transport override (``dist.transport``);
        defaults to plain named-axis collectives over ``axes``.  The
        simulator injects a ``MaskedTransport`` here to drop per-worker
        payloads (worker dropout) without touching the wire-mode code.
      codec: wire codec override (``core.codec``); defaults to the
        scheme's uniform codec.  A ``MixedWidthCodec`` threads per-bucket
        widths through the same transports; a ``SparseCodec``
        (``repro.compress``) moves top-k index+value payloads.
      return_own: also return this worker's OWN lossy round trip
        ``Q(flat)`` (the decode of the bytes it put on the wire) —
        what the ``repro.compress`` error-feedback layer derives its
        residual from, at zero additional wire bytes.

    Returns (aggregate mean, SyncMetrics) — or (aggregate, own,
    SyncMetrics) with ``return_own`` — where the aggregate is
    bit-identical on every worker in all modes.
    """
    flat = flat.reshape(-1)
    axes = tuple(axes)
    if transport is None:
        transport = make_transport(axes)
    if mode == "fp32" or not scheme.quantized:
        out = transport.mean_psum(flat)
        m = SyncMetrics(jnp.float32(32.0), jnp.float32(0.0),
                        jnp.float32(32.0), jnp.float32(0.0),
                        jnp.float32(32.0))
        # fp32 sync is lossless: the own round trip is the input itself
        return (out, flat, m) if return_own else (out, m)
    if codec is None:
        codec = codec_for_scheme(scheme)

    levels = state.levels
    if transport.axes:
        key = jax.random.fold_in(key, transport.rank())
    if mode == "all_gather":
        out, own, m = _allreduce_all_gather(flat, codec, levels, key,
                                            transport, use_pallas)
    elif mode == "two_phase":
        out, own, m = _allreduce_two_phase(flat, codec, levels, key,
                                           transport, use_pallas)
    else:
        raise ValueError(f"unknown sync mode {mode!r}")
    ent = jnp.asarray(state.entropy_bits, jnp.float32)
    m = m._replace(entropy_bits_per_coord=ent)
    return (out, own, m) if return_own else (out, m)


def compressed_allreduce(
    flat: jnp.ndarray,
    scheme: QuantScheme,
    state: SchemeState,
    algorithm,
    comp_state,
    key: jax.Array,
    *,
    axes=(),
    mode: str = "all_gather",
    use_pallas: bool = True,
    transport: Transport | None = None,
) -> tuple:
    """The ``repro.compress`` algorithm hook around ENCODE/DECODE.

    Sequences ``algorithm.prepare`` (error-feedback residual injection)
    -> ``quantized_allreduce`` on the algorithm's codec ->
    ``algorithm.feedback`` (residual update from the codec's own local
    decode — zero additional wire bytes).  With the stateless ``plain``
    algorithm this is bit-for-bit ``quantized_allreduce`` on the same
    codec (``comp_state`` may then be ``None``).

    Returns (aggregate mean, new comp_state, SyncMetrics); the metrics
    carry the algorithm accounting (``residual_norm``,
    ``kept_fraction``) next to the wire accounting.
    """
    flat = flat.reshape(-1)
    inp = algorithm.prepare(flat, comp_state)
    out, own, m = quantized_allreduce(
        inp, scheme, state, key, axes=axes, mode=mode,
        use_pallas=use_pallas, transport=transport,
        codec=algorithm.codec, return_own=True)
    new_state = algorithm.feedback(comp_state, inp, own)
    m = m._replace(residual_norm=algorithm.residual_norm(new_state),
                   kept_fraction=jnp.float32(algorithm.kept_fraction))
    return out, new_state, m


# ---------------------------------------------------------------------------
# sufficient statistics + schedule-gated level update
# ---------------------------------------------------------------------------

def gather_stats(
    flat: jnp.ndarray,
    scheme: QuantScheme,
    *,
    axes=(),
    use_pallas: bool = True,
) -> TruncNormStats:
    """One-sweep sufficient statistics of the local gradient, merged
    across workers (Algorithm 1, line 4).

    A single fused ``bucket_stats`` pass emits per-bucket (norm, mean_r,
    var_r); only ``max_stat_components`` scalars per worker travel in the
    merge — this is the only communication the adaptive methods add.
    """
    flat = flat.reshape(-1)
    axes = tuple(axes)
    codec = codec_for_scheme(scheme)
    vb = codec.bucketize(flat, codec.plan(flat.shape[0]))
    norms, mu, var = ops.bucket_stats_op(vb, norm_type=scheme.norm_type,
                                         use_pallas=use_pallas)
    # keep only fully-populated buckets: alignment padding is all-zero,
    # and a trailing partial bucket's intra-bucket zeros would bias its
    # (mu, sigma) toward 0 — drop it unless it is the only bucket
    nb_valid = max(flat.shape[0] // scheme.bucket_size, 1)
    stats = stats_from_moments(
        mu[:nb_valid], var[:nb_valid], norms[:nb_valid],
        weighted=scheme.weighted_stats,
        max_components=scheme.max_stat_components)
    if axes:
        stats = merge_stats(stats, axes)
    return stats


def maybe_update_levels(
    flat: jnp.ndarray,
    scheme: QuantScheme,
    state: SchemeState,
    do_update,
    *,
    axes=(),
    use_pallas: bool = True,
) -> SchemeState:
    """Run the scheme's level adaptation iff ``do_update`` (traced bool).

    ``lax.cond``-gated: on non-update steps neither the stats sweep nor
    the (tiny) merge collective executes — the adaptive methods' extra
    cost lands only on the paper's sparse schedule (App. K).
    """
    if not scheme.adaptive:
        return state
    flat = jax.lax.stop_gradient(flat.reshape(-1))

    def upd(s):
        stats = gather_stats(flat, scheme, axes=axes, use_pallas=use_pallas)
        return scheme.update_state(s, stats)

    return jax.lax.cond(do_update, upd, lambda s: s, state)

"""Injectable collective transport for the quantized sync engine.

``dist.sync``'s wire modes are written against this small protocol
instead of calling ``jax.lax`` collectives directly, so the same
ENCODE -> collective -> DECODE code path runs in three settings:

  * inside ``shard_map`` over mesh axes (production: ``MeshTransport``);
  * inside ``jax.vmap(..., axis_name=...)`` — vmap axes are first-class
    named axes in jax, so ``MeshTransport`` doubles as the single-host
    M-logical-worker transport the ``repro.sim`` cluster simulator uses;
  * with per-worker payload *weighting* injected on top
    (``MaskedTransport``), which is how the simulator models worker
    dropout: a dropped worker's payload never arrives and is excluded
    from the aggregate (the cluster cost model likewise treats the
    worker as absent for the step).

A transport also owns the cross-worker averaging rule
(``mean_workers``): the plain transports average uniformly; the masked
transport renormalizes over surviving workers, so every wire mode gets
dropout support without knowing about it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def axes_size(axes) -> int:
    """Total worker count over the (ordered) named axes (static)."""
    n = 1
    for ax in axes:
        n *= jax.lax.axis_size(ax)
    return n


def axes_rank(axes):
    """Row-major global rank over the (ordered) named axes."""
    r = jnp.zeros((), jnp.int32)
    for ax in axes:
        r = r * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
    return r


class Transport:
    """Collectives over an ordered tuple of named axes.

    The base class implements everything with ``jax.lax`` primitives;
    named axes may be mesh axes (under ``shard_map``) or vmap axes.
    """

    def __init__(self, axes=()):
        self.axes = tuple(axes)

    # ---- topology facts -------------------------------------------------

    def size(self) -> int:
        return axes_size(self.axes)

    def rank(self):
        return axes_rank(self.axes)

    # ---- collectives ----------------------------------------------------

    def all_gather(self, x):
        """(…) -> (M, …) with worker w's payload at row w."""
        if not self.axes:
            return x[None]
        return jax.lax.all_gather(x, self.axes)

    def all_to_all(self, x):
        """(M, …) -> (M, …): row j goes to worker j; row i of the result
        is what worker i sent to this worker (tiled exchange over axis 0)."""
        if not self.axes or self.size() == 1:
            return x
        return jax.lax.all_to_all(x, self.axes, 0, 0, tiled=True)

    def psum(self, x):
        if not self.axes:
            return x
        return jax.lax.psum(x, self.axes)

    # ---- aggregation rule ----------------------------------------------

    def weights(self) -> jnp.ndarray:
        """(M,) convex weights used to average per-worker payloads."""
        M = self.size()
        return jnp.full((M,), 1.0 / M, jnp.float32)

    def active_vector(self) -> jnp.ndarray:
        """(M,) raw per-worker delivery weights BEFORE renormalization
        (1.0 everywhere for the plain transports).  The per-bucket
        exclusion rule (``mean_workers_bucketed``) renormalizes from
        this so its float ops match ``MaskedTransport.weights`` exactly
        when the validity mask is constant across buckets."""
        return jnp.ones((self.size(),), jnp.float32)

    def mean_workers(self, stacked: jnp.ndarray) -> jnp.ndarray:
        """Mean over the leading (worker) axis of gathered payloads.

        The uniform case MUST stay ``stacked.mean(0)`` (sum then divide):
        the packed-vs-unpacked bit-exactness contract of the wire format
        pins this exact float reduction order.
        """
        return stacked.mean(0)

    def mean_workers_bucketed(self, stacked: jnp.ndarray,
                              valid: jnp.ndarray,
                              bucket_size: int) -> jnp.ndarray:
        """Per-bucket masked mean over workers: ``stacked`` is (M, n)
        gathered values, ``valid`` an (M, nb) bool mask of buckets that
        passed integrity checks; detected-corrupt buckets are excluded
        and the rest renormalized, per bucket, with the SAME formula as
        ``MaskedTransport.weights`` (``a / max(sum(a), 1.0)`` from the
        raw active vector) so a worker whose every bucket is invalid
        aggregates bit-exactly like one masked out at the transport.
        An all-invalid bucket aggregates to 0 (dropped coordinate).
        """
        M = stacked.shape[0]
        nb = valid.shape[1]
        a = self.active_vector()[:, None] * valid.astype(jnp.float32)
        w = a / jnp.maximum(jnp.sum(a, axis=0), 1.0)      # (M, nb)
        vb = stacked.reshape(M, nb, bucket_size)
        # corrupted buckets can decode to NaN/Inf (corrupt norm words);
        # their weight is 0 but 0 * NaN = NaN, so zero the values too
        vb = jnp.where(valid[:, :, None], vb, 0.0)
        return jnp.einsum("mb,mbc->bc", w, vb).reshape(-1)

    def mean_psum(self, x: jnp.ndarray) -> jnp.ndarray:
        """fp32 mean-allreduce of per-worker local values."""
        if not self.axes:
            return x
        return jax.lax.psum(x, self.axes) / self.size()


class MeshTransport(Transport):
    """Production transport: ``jax.lax`` collectives over named axes
    (mesh axes inside ``shard_map``, or vmap axes with ``axis_name``)."""


class MaskedTransport(Transport):
    """Wraps named-axis collectives with an injected per-worker weight
    vector — the simulator's dropout / heterogeneity hook.

    ``active`` is an (M,) float vector (1.0 = payload arrives, 0.0 =
    worker absent); weights renormalize over the survivors, so the
    aggregate is the mean over workers whose payloads were delivered.
    ``active`` must be replicated across workers (it is the *cluster's*
    state for the step, not a per-worker view).
    """

    def __init__(self, axes, active: jnp.ndarray):
        super().__init__(axes)
        self.active = jnp.asarray(active, jnp.float32)

    def weights(self) -> jnp.ndarray:
        total = jnp.maximum(jnp.sum(self.active), 1.0)
        return self.active / total

    def active_vector(self) -> jnp.ndarray:
        return self.active

    def mean_workers(self, stacked: jnp.ndarray) -> jnp.ndarray:
        return jnp.tensordot(self.weights(), stacked, axes=(0, 0))

    def mean_psum(self, x: jnp.ndarray) -> jnp.ndarray:
        if not self.axes:
            return x
        return jax.lax.psum(
            x * jnp.take(self.weights(), self.rank()), self.axes)


def make_transport(axes=(), active=None) -> Transport:
    """Default transport factory used by ``quantized_allreduce``."""
    if active is not None:
        return MaskedTransport(axes, active)
    return MeshTransport(axes)

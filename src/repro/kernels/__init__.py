"""Pallas TPU kernels for the quantization hot path (+ jnp oracles)."""
from .ops import bucket_stats_op, dequantize_op, quantize_op
from .quantize import quantize_pallas
from .dequantize import dequantize_pallas
from .bucket_stats import bucket_stats_pallas

"""Pallas TPU kernel: fused sufficient statistics (Algorithm 1, line 4).

One pass over the bucketed gradient computes, per bucket, the Lq norm and
the first two moments of the normalized magnitudes — exactly what
``repro.core.stats.fit_bucket_stats`` needs to fit the truncated-normal
mixture.  Fusing avoids a second HBM sweep over the gradient (the
adaptive methods' extra cost is this kernel once every ~10k steps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantize import NORM_L2, NORM_LINF
from .quantize import DEFAULT_BUCKET_TILE


def _bucket_stats_kernel(v_ref, norms_ref, mu_ref, var_ref, *, norm_type: str):
    v = v_ref[...].astype(jnp.float32)
    if norm_type == NORM_L2:
        norm = jnp.sqrt(jnp.sum(v * v, axis=-1))
    elif norm_type == NORM_LINF:
        norm = jnp.max(jnp.abs(v), axis=-1)
    else:
        raise ValueError(norm_type)
    safe = jnp.where(norm > 0, norm, 1.0)
    r = jnp.abs(v) / safe[:, None]
    mu = jnp.mean(r, axis=-1)
    var = jnp.mean(r * r, axis=-1) - mu * mu
    norms_ref[...] = norm
    mu_ref[...] = mu
    var_ref[...] = jnp.maximum(var, 0.0)


@functools.partial(
    jax.jit, static_argnames=("norm_type", "bucket_tile", "interpret")
)
def bucket_stats_pallas(
    vb: jnp.ndarray,
    *,
    norm_type: str = NORM_L2,
    bucket_tile: int = DEFAULT_BUCKET_TILE,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns per-bucket (norms, mean_r, var_r), each (num_buckets,)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nb, bs = vb.shape
    bucket_tile = min(bucket_tile, nb)
    if nb % bucket_tile:
        raise ValueError(f"num_buckets {nb} % bucket_tile {bucket_tile} != 0")
    grid = (nb // bucket_tile,)
    kernel = functools.partial(_bucket_stats_kernel, norm_type=norm_type)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bucket_tile, bs), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bucket_tile,), lambda i: (i,)),
            pl.BlockSpec((bucket_tile,), lambda i: (i,)),
            pl.BlockSpec((bucket_tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(vb)

"""Pallas TPU kernel: decode (DECODE_l, Algorithm 1 line 8).

codes (int8 signed level indices) + per-bucket norms -> f32 values.
Same bucket-tile layout as quantize.py.  The level lookup is a one-hot
contraction (VPU) rather than a gather — TPU-native for tiny tables.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quantize import DEFAULT_BUCKET_TILE


def _dequantize_kernel(codes_ref, norms_ref, levels_ref, out_ref):
    codes = codes_ref[...].astype(jnp.int32)
    norms = norms_ref[...]
    levels = levels_ref[...]

    idx = jnp.abs(codes)
    nlev = levels.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, idx.shape + (nlev,), idx.ndim)
    onehot = (iota == idx[..., None]).astype(jnp.float32)
    mags = jnp.sum(onehot * levels[None, None, :], axis=-1)
    sign = jnp.sign(codes).astype(jnp.float32)
    out_ref[...] = mags * sign * norms[:, None]


@functools.partial(jax.jit, static_argnames=("bucket_tile", "interpret"))
def dequantize_pallas(
    codes: jnp.ndarray,
    norms: jnp.ndarray,
    levels: jnp.ndarray,
    *,
    bucket_tile: int = DEFAULT_BUCKET_TILE,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nb, bs = codes.shape
    bucket_tile = min(bucket_tile, nb)
    if nb % bucket_tile:
        raise ValueError(f"num_buckets {nb} % bucket_tile {bucket_tile} != 0")
    grid = (nb // bucket_tile,)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bucket_tile, bs), lambda i: (i, 0)),
            pl.BlockSpec((bucket_tile,), lambda i: (i,)),
            pl.BlockSpec(levels.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bucket_tile, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bs), jnp.float32),
        interpret=interpret,
    )(codes, norms, levels)

"""Public jit'd entry points for the Pallas kernels.

``use_pallas`` switches between the Pallas path (interpret-mode on CPU,
compiled on TPU) and the pure-jnp oracle — the distributed sync layer
calls through here so the whole framework runs on either.

The entry points also pick a *valid* bucket tile for the kernels: the
Pallas grid requires ``num_buckets % bucket_tile == 0``, and the sync /
FSDP layers produce bucket counts that are bucket- and shard-aligned but
not always tile-aligned (e.g. a reduce-scatter round of M*ppr buckets).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import NORM_L2
from . import ref
from .bucket_stats import bucket_stats_pallas
from .dequantize import dequantize_pallas
from .quantize import DEFAULT_BUCKET_TILE, quantize_pallas


def _tile_for(num_buckets: int) -> int:
    """Largest tile <= DEFAULT_BUCKET_TILE that divides num_buckets."""
    t = min(DEFAULT_BUCKET_TILE, num_buckets)
    while num_buckets % t:
        t -= 1
    return t


def quantize_op(
    vb: jnp.ndarray,
    u: jnp.ndarray,
    levels: jnp.ndarray,
    *,
    norm_type: str = NORM_L2,
    use_pallas: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    if use_pallas:
        return quantize_pallas(vb, u, levels, norm_type=norm_type,
                               bucket_tile=_tile_for(vb.shape[0]))
    return ref.quantize_ref(vb, u, levels, norm_type)


def dequantize_op(
    codes: jnp.ndarray,
    norms: jnp.ndarray,
    levels: jnp.ndarray,
    *,
    use_pallas: bool = True,
) -> jnp.ndarray:
    if use_pallas:
        return dequantize_pallas(codes, norms, levels,
                                 bucket_tile=_tile_for(codes.shape[0]))
    return ref.dequantize_ref(codes, norms, levels)


def bucket_stats_op(
    vb: jnp.ndarray, *, norm_type: str = NORM_L2, use_pallas: bool = True
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    if use_pallas:
        return bucket_stats_pallas(vb, norm_type=norm_type,
                                   bucket_tile=_tile_for(vb.shape[0]))
    return ref.bucket_stats_ref(vb, norm_type)

"""Pallas TPU kernel: fused bucket-norm + normalize + stochastic round.

This is the per-step encode hot path of Algorithm 1 (line 6).  On GPU the
paper uses a CUDA kernel; the TPU adaptation tiles *buckets* into VMEM:

  grid      = (num_buckets // BUCKET_TILE,)
  v block   = (BUCKET_TILE, bucket_size)   f32 in VMEM
  u block   = (BUCKET_TILE, bucket_size)   f32 in VMEM (pre-drawn uniforms;
              randomness is an explicit input so the kernel is a pure
              function and bit-identical to the oracle)
  levels    = (num_levels,)                full, replicated to every tile
  codes out = (BUCKET_TILE, bucket_size)   int8
  norms out = (BUCKET_TILE,)               f32

The bucket reduction (norm) runs on the VPU along lanes; the level search
is a broadcast compare against the (tiny) level vector — no gather, no
sort, MXU stays free for the overlapping backward matmuls.  A bucket is
always resident in one tile (bucket_size is the minor, lane-aligned dim;
8192 = 64 lanes * 128 sublanes exactly fills a VREG-friendly tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantize import NORM_L2, NORM_LINF, code_dtype

DEFAULT_BUCKET_TILE = 8


def _quantize_kernel(v_ref, u_ref, levels_ref, codes_ref, norms_ref, *, norm_type: str):
    v = v_ref[...].astype(jnp.float32)
    u = u_ref[...]
    levels = levels_ref[...]

    if norm_type == NORM_L2:
        norm = jnp.sqrt(jnp.sum(v * v, axis=-1))
    elif norm_type == NORM_LINF:
        norm = jnp.max(jnp.abs(v), axis=-1)
    else:
        raise ValueError(norm_type)

    safe = jnp.where(norm > 0, norm, 1.0)
    r = jnp.clip(jnp.abs(v) / safe[:, None], 0.0, 1.0)

    # level search: tau = (#levels <= r) - 1, via broadcast compare.
    tau = jnp.sum(
        (r[..., None] >= levels[None, None, :]).astype(jnp.int32), axis=-1
    ) - 1
    tau = jnp.clip(tau, 0, levels.shape[0] - 2)

    # gather-free level lookup: one-hot contraction against the level vec.
    nlev = levels.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, r.shape + (nlev,), len(r.shape))
    onehot_lo = (iota == tau[..., None]).astype(jnp.float32)
    onehot_hi = (iota == (tau + 1)[..., None]).astype(jnp.float32)
    lo = jnp.sum(onehot_lo * levels[None, None, :], axis=-1)
    hi = jnp.sum(onehot_hi * levels[None, None, :], axis=-1)

    rho = (r - lo) / jnp.maximum(hi - lo, 1e-30)
    idx = tau + (u < rho).astype(jnp.int32)
    sign = jnp.where(v > 0, 1, jnp.where(v < 0, -1, 0))

    codes_ref[...] = (idx * sign).astype(codes_ref.dtype)
    norms_ref[...] = norm


@functools.partial(
    jax.jit, static_argnames=("norm_type", "bucket_tile", "interpret")
)
def quantize_pallas(
    vb: jnp.ndarray,
    u: jnp.ndarray,
    levels: jnp.ndarray,
    *,
    norm_type: str = NORM_L2,
    bucket_tile: int = DEFAULT_BUCKET_TILE,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize bucketed gradients; returns (codes int8, norms f32).

    vb, u: (num_buckets, bucket_size).  num_buckets must be divisible by
    bucket_tile (callers pad; repro.dist.sync does).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nb, bs = vb.shape
    bucket_tile = min(bucket_tile, nb)
    if nb % bucket_tile:
        raise ValueError(f"num_buckets {nb} % bucket_tile {bucket_tile} != 0")
    grid = (nb // bucket_tile,)
    kernel = functools.partial(_quantize_kernel, norm_type=norm_type)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bucket_tile, bs), lambda i: (i, 0)),
            pl.BlockSpec((bucket_tile, bs), lambda i: (i, 0)),
            pl.BlockSpec(levels.shape, lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bucket_tile, bs), lambda i: (i, 0)),
            pl.BlockSpec((bucket_tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bs), code_dtype(levels.shape[0])),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(vb, u, levels)

"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
They operate on already-bucketed inputs: v is (num_buckets, bucket_size).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quantize import NORM_L2, NORM_LINF, code_dtype


def _norms(vb: jnp.ndarray, norm_type: str) -> jnp.ndarray:
    if norm_type == NORM_L2:
        return jnp.sqrt(jnp.sum(vb.astype(jnp.float32) ** 2, axis=-1))
    if norm_type == NORM_LINF:
        return jnp.max(jnp.abs(vb.astype(jnp.float32)), axis=-1)
    raise ValueError(norm_type)


def quantize_ref(
    vb: jnp.ndarray, u: jnp.ndarray, levels: jnp.ndarray, norm_type: str
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused bucket-norm + normalize + stochastic round.

    Returns (codes int8 signed level indices, norms f32).
    """
    norms = _norms(vb, norm_type)
    safe = jnp.where(norms > 0, norms, 1.0)
    r = jnp.clip(jnp.abs(vb.astype(jnp.float32)) / safe[:, None], 0.0, 1.0)
    # tau = #levels <= r, minus one (levels sorted, levels[0]=0 so tau>=0);
    # searchsorted keeps the temp at O(nb*bucket), not O(nb*bucket*levels)
    tau = jnp.searchsorted(levels, r, side="right") - 1
    tau = jnp.clip(tau, 0, levels.shape[0] - 2)
    lo = levels[tau]
    hi = levels[tau + 1]
    rho = (r - lo) / jnp.maximum(hi - lo, 1e-30)
    idx = tau + (u < rho)
    sign = jnp.sign(vb).astype(jnp.int32)
    # int8 up to 128 levels (bits <= 7); the 8-bit edge widens to int16
    return (idx * sign).astype(code_dtype(levels.shape[0])), norms.astype(
        jnp.float32)


def dequantize_ref(
    codes: jnp.ndarray, norms: jnp.ndarray, levels: jnp.ndarray
) -> jnp.ndarray:
    """codes (signed, any int dtype) + norms -> f32 (num_buckets, bucket)."""
    idx = jnp.abs(codes.astype(jnp.int32))
    mags = jnp.take(levels.astype(jnp.float32), idx)
    return mags * jnp.sign(codes.astype(jnp.float32)) * norms[:, None]


def bucket_stats_ref(
    vb: jnp.ndarray, norm_type: str
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused sufficient statistics: per-bucket (norm, mean_r, var_r)."""
    norms = _norms(vb, norm_type)
    safe = jnp.where(norms > 0, norms, 1.0)
    r = jnp.abs(vb.astype(jnp.float32)) / safe[:, None]
    mu = jnp.mean(r, axis=-1)
    var = jnp.mean(r * r, axis=-1) - mu * mu
    return norms.astype(jnp.float32), mu, jnp.maximum(var, 0.0)

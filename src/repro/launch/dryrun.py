import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers and compiles on the production mesh, and extract the
roofline terms from the compiled artifact.

The two lines above MUST precede any other import (jax locks the device
count at first init); do not set that flag globally — smoke tests and
benchmarks should see one device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --sync all_gather --out experiments/dryrun
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES, input_specs
from repro.core.schemes import QuantScheme
from repro.launch import hlo_analysis, jaxpr_cost
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.models.transformer import Model
from repro.train.optim import OptimConfig
from repro.train.train_step import (
    TrainConfig, TrainState, init_train_state, make_train_step)

# archs whose long_500k is skipped (pure full-attention; DESIGN.md §4)
LONG_SKIP = {
    "qwen1.5-32b", "qwen3-0.6b", "granite-3-2b", "llama3.2-1b",
    "llama-3.2-vision-11b", "musicgen-large",
}


FSDP_BYTES_THRESHOLD = 6e9  # per-device params(+opt) budget before FSDP
ACTIVATION_BUDGET = 8e9     # per-device activation bytes before microbatching


def auto_microbatches(cfg, shape, mesh) -> int:
    """Smallest power-of-two microbatch count whose per-device activation
    estimate (~3 x layers x B_micro x S x d bf16, the scan-carry residuals
    plus in-layer bwd transients) fits the budget."""
    data_axes, model_axis = mesh_axes(mesh)
    dp = 1
    for ax in data_axes:
        dp *= mesh.shape[ax]
    b_local = max(shape.global_batch // dp, 1)
    micro = 1
    while micro < b_local:
        b_micro = b_local // micro
        est = 3.0 * cfg.num_layers * b_micro * shape.seq_len * cfg.d_model * 2
        if est <= ACTIVATION_BUDGET:
            break
        micro *= 2
    return micro


def build_model(cfg, mesh, shape, scheme=None, sync_mode="all_gather"):
    data_axes, model_axis = mesh_axes(mesh)
    tp = mesh.shape[model_axis]
    dp = 1
    for ax in data_axes:
        dp *= mesh.shape[ax]
    if shape.kind == "decode" and shape.global_batch < dp:
        # batch-1 long-context: shard the cache sequence over everything
        seq_axes = tuple(data_axes) + (model_axis,)
        batch_axes = ()
    else:
        seq_axes = (model_axis,)
        batch_axes = tuple(data_axes)
    # params(+grads+momentum) per device under DP replication:
    n = cfg.param_count()
    per_dev = n * (12 if shape.kind == "train" else 4) / tp
    param_mode = "fsdp" if per_dev > FSDP_BYTES_THRESHOLD else "dp"
    fsdp_sync = ("quantized" if shape.kind == "train"
                 and sync_mode != "fp32" else "fp32")
    model = Model(cfg, tp=tp, dp=dp, data_axes=data_axes,
                  seq_shard_axes=seq_axes, param_mode=param_mode,
                  fsdp_scheme=scheme, fsdp_sync=fsdp_sync)
    return model, batch_axes, data_axes


def lower_pair(cfg, shape, mesh, *, sync_mode="all_gather",
               scheme_name="alq", bits=3, bucket=8192,
               microbatches=1, remat="full"):
    """Lower + compile one (arch, shape, mesh) combination.

    Returns (compiled, jaxpr_cost, lower_seconds, compile_seconds).
    """
    scheme = QuantScheme(name=scheme_name, bits=bits, bucket_size=bucket)
    model, batch_axes, data_axes = build_model(cfg, mesh, shape, scheme,
                                               sync_mode)
    model.remat = remat
    pspecs = model.param_specs()
    pstruct = model.param_struct()
    specs = input_specs(cfg, shape)
    bspec = P(batch_axes) if batch_axes else P()

    if shape.kind == "train":
        # use_pallas=False: on CPU the Pallas kernels run in interpret
        # mode, which materializes every grid block at once — fine for
        # kernel tests, wrong for memory analysis.  On real TPU the
        # compiled pallas_call path is enabled (launch/train.py).
        tcfg = TrainConfig(scheme=scheme, optim=OptimConfig(name="sgdm"),
                           sync_mode=sync_mode, microbatches=microbatches,
                           use_pallas=False)
        step = make_train_step(model, tcfg, data_axes=data_axes)
        state_struct = jax.eval_shape(
            lambda: init_train_state(model, tcfg, jax.random.PRNGKey(0)))
        state_specs = TrainState(
            params=pspecs,
            opt=type(state_struct.opt)(
                mu=pspecs,
                nu=None if state_struct.opt.nu is None else pspecs,
                count=P()),
            scheme_state=jax.tree.map(lambda _: P(),
                                      state_struct.scheme_state),
            step=P(), rng=P())
        batch_specs = {k: bspec for k in specs}

        def fn(state, batch):
            return step(state, batch)

        from repro.train.train_step import metric_specs
        smapped = jax.shard_map(
            fn, mesh=mesh, in_specs=(state_specs, batch_specs),
            out_specs=(state_specs, metric_specs()),
            check_vma=False)
        args = (state_struct, specs)

    elif shape.kind == "prefill":
        cache_shards = model.tp
        cspecs = model.cache_pspecs(batch_axes)
        cstruct = model.global_cache_struct(
            shape.global_batch, shape.seq_len, cache_shards)

        def fn(params, batch):
            return model.prefill(params, batch["ids"],
                                 batch.get("vision"),
                                 max_len=shape.seq_len,
                                 cache_shards=cache_shards)

        smapped = jax.shard_map(
            fn, mesh=mesh, in_specs=(pspecs, {k: bspec for k in specs}),
            out_specs=(bspec, cspecs), check_vma=False)
        args = (pstruct, specs)

    else:  # decode
        cache_shards = 1
        for ax in model.seq_shard_axes:
            cache_shards *= mesh.shape[ax]
        cspecs = model.cache_pspecs(batch_axes)
        cstruct = model.global_cache_struct(
            shape.global_batch, shape.seq_len, cache_shards)
        vision_struct = specs.pop("vision", None)

        def fn(params, token, pos, caches):
            logits, new_caches = model.decode(
                params, token, pos, caches, None,
                cache_shards=cache_shards)
            return jnp.argmax(logits, -1).astype(jnp.int32), new_caches

        smapped = jax.shard_map(
            fn, mesh=mesh,
            in_specs=(pspecs, bspec, bspec, cspecs),
            out_specs=(bspec, cspecs), check_vma=False)
        args = (pstruct, specs["token"], specs["pos"], cstruct)

    with jax.set_mesh(mesh):
        t0 = time.time()
        acost = jaxpr_cost.analyze_fn(smapped, *args)
        lowered = jax.jit(smapped).lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    return compiled, acost, t1 - t0, t2 - t1


def run_one(arch, shape_name, mesh_kind, *, sync_mode, out_dir,
            scheme_name="alq", bits=3, tag="", microbatches=1,
            remat="full"):
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "sync": sync_mode, "scheme": scheme_name, "bits": bits,
        "chips": mesh.size, "tag": tag, "microbatches": microbatches,
        "remat": remat,
    }
    if microbatches == 0 and SHAPES[shape_name].kind == "train":
        microbatches = auto_microbatches(cfg, SHAPES[shape_name], mesh)
        rec["microbatches"] = microbatches
    try:
        compiled, acost, t_low, t_comp = lower_pair(
            cfg, shape, mesh, sync_mode=sync_mode,
            scheme_name=scheme_name, bits=bits,
            microbatches=microbatches, remat=remat)
        mem = compiled.memory_analysis()
        hlo_roof = hlo_analysis.analyze(compiled)
        # primary roofline terms from the jaxpr walker (scan-exact);
        # compiled cost_analysis kept as a secondary record
        roof = hlo_analysis.Roofline(
            flops_per_device=acost.flops,
            hbm_bytes_per_device=acost.hbm_bytes,
            collective_wire_bytes=acost.collective_bytes,
            bytes_by_kind=acost.by_collective,
        )
        # model flops: 6*N_active*D for training, 2*N_active*D prefill,
        # 2*N_active*B decode
        n_act = cfg.active_param_count()
        shp = SHAPES[shape_name]
        tokens = shp.global_batch * (shp.seq_len if shp.kind != "decode"
                                     else 1)
        mult = 6 if shp.kind == "train" else 2
        model_flops_per_dev = mult * n_act * tokens / mesh.size
        rec.update({
            "ok": True,
            "lower_s": round(t_low, 2),
            "compile_s": round(t_comp, 2),
            "bytes_per_device": {
                "argument": mem.argument_size_in_bytes,
                "output": mem.output_size_in_bytes,
                "temp": mem.temp_size_in_bytes,
                "total": (mem.argument_size_in_bytes
                          + mem.temp_size_in_bytes),
            },
            "roofline": roof.to_dict(),
            "model_flops_per_device": model_flops_per_dev,
            "useful_flops_ratio": (model_flops_per_dev
                                   / max(roof.flops_per_device, 1.0)),
            "hlo_cost_analysis": hlo_roof.to_dict(),
        })
        print(f"[OK] {arch} x {shape_name} x {mesh_kind}"
              f" flops/dev={roof.flops_per_device:.3e}"
              f" wire={roof.collective_wire_bytes:.3e}B"
              f" dom={roof.dominant}"
              f" useful={rec['useful_flops_ratio']:.2f}"
              f" mem={rec['bytes_per_device']['total']/2**30:.1f}GiB"
              f" (lower {t_low:.0f}s compile {t_comp:.0f}s)")
    except Exception as e:  # record failures — they are bugs to fix
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:]})
        print(f"[FAIL] {arch} x {shape_name} x {mesh_kind}: {e}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fn = os.path.join(
            out_dir, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--sync", default="all_gather",
                    choices=["fp32", "all_gather", "two_phase"])
    ap.add_argument("--scheme", default="alq")
    ap.add_argument("--bits", type=int, default=3)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--micro", type=int, default=0,
                    help="microbatches per step; 0 = auto-size")
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "psum", "none"])
    args = ap.parse_args()

    archs = configs.ARCH_NAMES if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    for arch in archs:
        cfg = configs.get_config(arch)
        for shape_name in shapes:
            if shape_name == "long_500k" and arch in LONG_SKIP:
                print(f"[SKIP] {arch} x long_500k (pure full attention; "
                      "DESIGN.md §4)")
                continue
            for mesh_kind in meshes:
                results.append(run_one(
                    arch, shape_name, mesh_kind, sync_mode=args.sync,
                    out_dir=args.out, scheme_name=args.scheme,
                    bits=args.bits, tag=args.tag,
                    microbatches=args.micro, remat=args.remat))
    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} combinations compiled")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Roofline-term extraction from compiled dry-run artifacts.

collective_bytes is NOT in cost_analysis(): we parse the optimized HLO
text and sum the result sizes of every collective op, weighted by its
wire pattern (all-reduce moves ~2x its payload on a ring; reduce-scatter
and all-gather ~1x; all-to-all and collective-permute 1x).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (constants from the assignment).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind result bytes summed over the module.

    Returns {kind: bytes, "total_wire": weighted bytes} where total_wire
    applies the ring-cost weighting described in the module docstring.
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # form: "%name = <shape> all-gather(...)" or "ROOT %x = <shape> op("
        m = re.search(r"=\s*(\([^)]*\)|\S+)\s+([\w-]+)", stripped)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-"):  # e.g. all-gather-start
                base = k
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue  # avoid double counting async pairs
        out[base] += _shape_bytes(shape_str)
    weights = {
        "all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
        "all-to-all": 1.0, "collective-permute": 1.0,
        "collective-broadcast": 1.0, "ragged-all-to-all": 1.0,
    }
    out["total_wire"] = sum(out[k] * weights[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_wire_bytes: float
    bytes_by_kind: dict

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_wire_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_wire_bytes": self.collective_wire_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bytes_by_kind": {k: v for k, v in self.bytes_by_kind.items()},
        }


def analyze(compiled) -> Roofline:
    cost = compiled.cost_analysis()
    cb = collective_bytes(compiled.as_text())
    return Roofline(
        flops_per_device=float(cost.get("flops", 0.0)),
        hbm_bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_wire_bytes=float(cb["total_wire"]),
        bytes_by_kind=cb,
    )

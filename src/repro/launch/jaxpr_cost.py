"""Analytic per-device cost model from the jaxpr (dry-run roofline input).

XLA's HloCostAnalysis counts a ``while`` body ONCE, so with scan-over-
layers the compiled ``cost_analysis()`` under-reports FLOPs by ~the layer
count.  This walker traverses the closed jaxpr instead, multiplying
nested ``scan`` bodies by their (static) trip count, and accounts:

  * flops: dot_general (2*b*m*n*k), conv, plus 1 flop per output element
    for elementwise/reduce ops (coarse but sub-dominant);
  * hbm bytes: operand+result bytes of every tensor op, i.e. an
    un-fused upper bound on HBM traffic (documented in EXPERIMENTS.md);
  * collective wire bytes by primitive (psum weighted 2x for its ring
    reduce+broadcast; gathers/all_to_alls 1x) — including collectives
    *inside* scans, which HLO-text parsing misses.

All numbers are per device: inside shard_map the jaxpr shapes are the
per-device block shapes.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np

ELEMENTWISE_FLOP_PRIMS = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "pow", "integer_pow", "neg", "abs",
    "erf", "cumsum", "cumlogsumexp", "select_n", "clamp", "and", "or",
    "xor", "not", "sign", "floor", "ceil", "round", "is_finite", "erf_inv",
}
REDUCE_PRIMS = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                "reduce_and", "reduce_or", "argmax", "argmin"}
# wire-weight per collective primitive
COLLECTIVE_WEIGHTS = {
    "psum": 2.0,            # ring all-reduce: reduce-scatter + all-gather
    "psum_invariant": 2.0,
    "all_gather": 1.0,
    "all_to_all": 1.0,
    "reduce_scatter": 1.0,
    "ppermute": 1.0,
    "pmax": 2.0,
    "pmin": 2.0,
}
# HBM-traffic model: with XLA fusion, elementwise chains fold into their
# producing/consuming matmuls, so we charge bytes only for "major" ops
# (matmul/conv operands+results, gathers/scatters, sorts, reductions) —
# a fused-traffic estimate rather than an unfused upper bound.
MAJOR_BYTES_PRIMS = {
    "dot_general", "conv_general_dilated", "gather", "scatter",
    "scatter-add", "scatter_add", "sort", "top_k", "cumsum",
    "dynamic_slice", "dynamic_update_slice",
} | REDUCE_PRIMS


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _nelems(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:
        return 0


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    by_collective: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.by_collective.items():
            self.by_collective[k] = self.by_collective.get(k, 0.0) + v * mult


def _dot_flops(eqn) -> float:
    (lhs, rhs), out = eqn.invars, eqn.outvars[0]
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    lshape = lhs.aval.shape
    k = math.prod(lshape[i] for i in lc) if lc else 1
    return 2.0 * _nelems(out.aval) * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval  # kernel
    # flops = 2 * out_elems * (kernel spatial x in-channels)
    k = math.prod(rhs.shape[:-1]) if rhs.shape else 1
    return 2.0 * _nelems(out) * k


def _eqn_cost(eqn) -> Cost:
    c = Cost()
    prim = eqn.primitive.name

    if prim == "dot_general":
        c.flops = _dot_flops(eqn)
    elif prim in ("conv_general_dilated",):
        c.flops = _conv_flops(eqn)
    elif prim in ELEMENTWISE_FLOP_PRIMS:
        c.flops = float(sum(_nelems(o.aval) for o in eqn.outvars))
    elif prim in REDUCE_PRIMS:
        c.flops = float(sum(_nelems(i.aval) for i in eqn.invars))

    if prim in COLLECTIVE_WEIGHTS:
        # payload = max(in, out): all_gather's wire ~ its (big) output,
        # reduce_scatter's ~ its (big) input, psum/all_to_all in == out.
        payload = max(
            sum(_nbytes(o.aval) for o in eqn.outvars),
            sum(_nbytes(i.aval) for i in eqn.invars if hasattr(i, "aval")),
        )
        wire = payload * COLLECTIVE_WEIGHTS[prim]
        c.collective_bytes = wire
        c.by_collective[prim] = wire
    elif prim in MAJOR_BYTES_PRIMS:
        c.hbm_bytes = float(
            sum(_nbytes(i.aval) for i in eqn.invars if hasattr(i, "aval"))
            + sum(_nbytes(o.aval) for o in eqn.outvars))
    return c


def _walk(jaxpr) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "scan":
            inner = _walk(eqn.params["jaxpr"].jaxpr)
            total.add(inner, mult=float(eqn.params["length"]))
        elif prim == "while":
            # trip count unknown statically; count the body once and the
            # caller should avoid unbounded whiles on hot paths (we do).
            inner = _walk(eqn.params["body_jaxpr"].jaxpr)
            total.add(inner, mult=1.0)
        elif prim == "cond":
            branches = [_walk(b.jaxpr) for b in eqn.params["branches"]]
            # worst-case branch
            worst = max(branches, key=lambda b: b.flops + b.hbm_bytes,
                        default=Cost())
            total.add(worst)
        elif "jaxpr" in eqn.params:
            sub = eqn.params["jaxpr"]
            total.add(_walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub))
        elif "call_jaxpr" in eqn.params:
            sub = eqn.params["call_jaxpr"]
            total.add(_walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub))
        else:
            total.add(_eqn_cost(eqn))
    return total


def analyze_fn(fn, *args) -> Cost:
    """Per-device analytic cost of `fn(*args)` (fn already shard_mapped —
    shapes inside the shard_map body are per-device)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return _walk(jaxpr.jaxpr)

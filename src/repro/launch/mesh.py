"""Mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
XLA_FLAGS for 512 placeholder devices before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 per pod, 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(tp: int = 1, *, pods: int = 1):
    """Best-effort mesh over whatever devices exist (tests / CPU runs)."""
    n = jax.device_count()
    tp = min(tp, n)
    dp = n // (tp * pods)
    if pods > 1:
        return jax.make_mesh((pods, dp, tp), ("pod", "data", "model"))
    return jax.make_mesh((dp, tp), ("data", "model"))


def mesh_axes(mesh) -> tuple[tuple, str]:
    """(data_axes, model_axis) for a mesh built by the functions above."""
    names = mesh.axis_names
    data_axes = tuple(n for n in names if n in ("pod", "data"))
    return data_axes, "model"

"""Serving launcher: batched prefill + decode of a (smoke) model on the
local mesh — the same serve_step the decode dry-run shapes lower.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --prompt-len 32 --gen 16 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_local_mesh, mesh_axes
from repro.models.transformer import Model
from repro.serve.engine import ServeConfig, make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--tp", type=int, default=1)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mesh = make_local_mesh(tp=args.tp)
    data_axes, model_axis = mesh_axes(mesh)
    tp = mesh.shape[model_axis]
    model = Model(cfg, tp=tp, dp=mesh.size // tp, data_axes=data_axes)
    max_len = args.prompt_len + args.gen
    scfg = ServeConfig(max_len=max_len)
    cache_shards = tp
    prefill = make_prefill_step(model, scfg, cache_shards=cache_shards)
    decode = make_decode_step(model, scfg, cache_shards=cache_shards)

    pspecs = model.param_specs()
    bspec = P(data_axes)
    cspecs = model.cache_pspecs(data_axes)
    with jax.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        pf = jax.jit(jax.shard_map(
            lambda p, i: prefill(p, i), in_specs=(pspecs, bspec),
            out_specs=(bspec, cspecs), check_vma=False))
        df = jax.jit(jax.shard_map(
            lambda p, t, pos, c: decode(p, t, pos, c),
            in_specs=(pspecs, bspec, bspec, cspecs),
            out_specs=(bspec, cspecs), check_vma=False))

        ids = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
        t0 = time.time()
        tok, caches = pf(params, ids)
        print(f"prefill({args.batch}x{args.prompt_len}) "
              f"{(time.time()-t0)*1e3:.0f} ms -> first tokens "
              f"{np.asarray(tok)}")
        out = [np.asarray(tok)]
        t0 = time.time()
        for i in range(args.gen - 1):
            pos = jnp.full((args.batch,), args.prompt_len + i, jnp.int32)
            tok, caches = df(params, tok, pos, caches)
            out.append(np.asarray(tok))
        dt = time.time() - t0
        gen = np.stack(out, 1)
        print(f"decoded {args.gen - 1} steps in {dt*1e3:.0f} ms "
              f"({dt/(args.gen-1)*1e3:.1f} ms/tok)")
        for b in range(min(args.batch, 2)):
            print(f"  seq[{b}]: {gen[b].tolist()}")


if __name__ == "__main__":
    main()

"""Training launcher.

On real hardware this drives the production mesh; on CPU it runs the
reduced (smoke) configs end-to-end — same code path, mesh (dp, tp) built
from whatever devices exist.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --smoke --scheme alq --bits 3 --steps 50 --sync all_gather
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core.schemes import QuantScheme
from repro.launch.mesh import make_local_mesh, mesh_axes
from repro.models.transformer import Model
from repro.train import checkpoint
from repro.train.data import DataConfig, Pipeline
from repro.train.optim import OptimConfig
from repro.train.train_step import (
    TrainConfig, TrainState, compress_state_specs, init_train_state,
    make_train_step, metric_specs)


def resume_state(ckpt_dir: str, state):
    """Auto-resume: (start_step, state) from the newest checkpoint in
    ``ckpt_dir`` (the FULL TrainState — optimizer moments, adapted
    levels, EF residual and all), or (0, state) for a fresh start."""
    found = checkpoint.restore_latest(ckpt_dir, state)
    if found is None:
        return 0, state
    step, restored = found
    print(f"resumed step {step} from "
          f"{checkpoint.step_path(ckpt_dir, step)}", flush=True)
    return step + 1, restored


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-proxy")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config for this arch")
    ap.add_argument("--scheme", default="alq")
    ap.add_argument("--bits", type=int, default=3)
    ap.add_argument("--bucket", type=int, default=1024)
    ap.add_argument("--sync", default="all_gather",
                    choices=["fp32", "all_gather", "two_phase"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optim", default="adamw", choices=["sgdm", "adamw"])
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--update-at", default="2,10")
    ap.add_argument("--codec", default="uniform",
                    choices=["uniform", "mixed_width", "entropy",
                             "entropy:uniform"],
                    help="wire codec: 'entropy' ships the entropy-coded "
                         "payload family (cold-start canonical-Huffman "
                         "table; bits/coord in the log is then the "
                         "MEASURED coded volume)")
    ap.add_argument("--widths", default="",
                    help="comma per-bucket scheme bits for "
                         "--codec mixed_width (cyclic pattern; empty = "
                         "the budget-neutral bits-1,bits+1 cycle)")
    ap.add_argument("--compress", default="plain",
                    help="compression algorithm around the codec "
                         "(repro.compress): plain | ef[:warmup] | "
                         "topk[:k]")
    ap.add_argument("--integrity", action="store_true", default=False,
                    help="lay per-bucket checksum words into the wire "
                         "payload; detected-corrupt buckets are "
                         "excluded from the aggregate")
    ap.add_argument("--save", default="")
    ap.add_argument("--ckpt-dir", default="",
                    help="checkpoint directory: enables periodic "
                         "TrainState saves and auto-resume from the "
                         "newest step_*.npz on restart")
    ap.add_argument("--save-every", type=int, default=0,
                    help="save the full TrainState to --ckpt-dir every "
                         "N steps (0 = only at the end)")
    ap.add_argument("--use-pallas", action="store_true", default=False)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mesh = make_local_mesh(tp=args.tp)
    data_axes, model_axis = mesh_axes(mesh)
    tp = mesh.shape[model_axis]
    dp = mesh.size // tp
    model = Model(cfg, tp=tp, dp=dp, data_axes=data_axes)

    scheme = QuantScheme(name=args.scheme, bits=args.bits,
                         bucket_size=args.bucket)
    tcfg = TrainConfig(
        scheme=scheme,
        optim=OptimConfig(name=args.optim, lr=args.lr, weight_decay=0.0),
        sync_mode=args.sync,
        update_milestones=tuple(int(x) for x in args.update_at.split(",")),
        update_every=0, microbatches=args.micro,
        use_pallas=args.use_pallas,
        codec=args.codec,
        mixed_width_pattern=tuple(
            int(x) for x in args.widths.split(",") if x),
        compress=args.compress,
        integrity=args.integrity)
    step_fn = make_train_step(model, tcfg, data_axes=data_axes)

    pipe = Pipeline(DataConfig(kind="markov", vocab_size=cfg.vocab_size,
                               seq_len=args.seq, global_batch=args.batch))
    pspecs = model.param_specs()
    bspec = P(data_axes)
    with jax.set_mesh(mesh):
        state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
        sspecs = TrainState(
            params=pspecs,
            opt=type(state.opt)(
                mu=pspecs,
                nu=None if state.opt.nu is None else pspecs, count=P()),
            scheme_state=jax.tree.map(lambda _: P(), state.scheme_state),
            step=P(), rng=P(),
            compress_state=compress_state_specs(state, data_axes))
        in_specs = (sspecs, {"ids": bspec, "labels": bspec})
        mspecs = metric_specs()
        train = jax.jit(jax.shard_map(step_fn, in_specs=in_specs,
                                      out_specs=(sspecs, mspecs),
                                      check_vma=False))
        start = 0
        if args.ckpt_dir:
            start, state = resume_state(args.ckpt_dir, state)
        t0 = time.time()
        for t in range(start, args.steps):
            state, metrics = train(state, pipe.batch(t))
            if args.ckpt_dir and (
                    (args.save_every > 0 and (t + 1) % args.save_every == 0)
                    or t == args.steps - 1):
                checkpoint.save_step(args.ckpt_dir, t, state)
            if t % 5 == 0 or t == args.steps - 1:
                extra = ("" if args.compress == "plain" else
                         f" |e|={float(metrics['residual_norm']):.3f}"
                         f" kept={float(metrics['kept_fraction']):.2f}")
                print(f"step {t:4d} loss={float(metrics['loss']):.4f} "
                      f"|g|={float(metrics['grad_norm']):.3f} "
                      f"bits/coord={float(metrics['comm_bits_per_coord']):.1f}"
                      f"{extra} "
                      f"levels={np.asarray(state.scheme_state.levels)[:4].round(3)}",
                      flush=True)
        dt = time.time() - t0
        print(f"done: {args.steps} steps in {dt:.1f}s "
              f"({dt / args.steps * 1e3:.0f} ms/step)")
        if args.save:
            checkpoint.save(args.save, state.params)
            print(f"saved params to {args.save}")


if __name__ == "__main__":
    main()

"""Model zoo: composable decoder stacks for the assigned architectures."""
from .config import ModelConfig
from .layers import TPCtx, make_dims
from .transformer import Model

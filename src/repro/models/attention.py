"""Attention: GQA with RoPE, qk-norm, qkv-bias; full / sliding-window /
chunked variants; cross-attention (VLM); decode against a
sequence-sharded KV cache.

Sharding scheme (see layers.Dims): query heads are sharded over the
``model`` axis (zero-padded to a multiple of tp, masked after attention);
the small GQA kv projection is replicated so every q head's kv head is
device-local for any (heads, kv, tp) combination.  Decode KV caches are
sharded along the *sequence* dim over ``model`` (and optionally the data
axes for batch-1 long-context); partial softmax statistics are combined
flash-style with pmax/psum — "sequence-parallel decode attention".

Training/prefill attention is a flash-style two-level loop in jnp:
``lax.map`` over query blocks, ``lax.while_loop`` with a *dynamic* trip
count over kv blocks, so causal/windowed FLOPs are exact and the live
working set is one (q_block, kv_block) tile per head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import CHUNKED, FULL, SLIDING, ModelConfig
from .layers import Dims, TPCtx, dense_init, head_mask, rms_norm, rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def attn_param_specs(cfg: ModelConfig, dims: Dims, cross: bool = False):
    d = cfg.d_model
    hd = dims.head_dim
    nkv = dims.n_kv_heads
    specs = {
        "wq": ((d, dims.heads_local * hd), d),
        "wk": ((d, nkv * hd), d),
        "wv": ((d, nkv * hd), d),
        "wo": ((dims.heads_local * hd, d), dims.n_heads * hd),
    }
    if cfg.qkv_bias and not cross:
        specs["bq"] = ((dims.heads_local * hd,), 0)
        specs["bk"] = ((nkv * hd,), 0)
        specs["bv"] = ((nkv * hd,), 0)
    if cfg.qk_norm and not cross:
        specs["q_norm"] = ((hd,), -1)
        specs["k_norm"] = ((hd,), -1)
    if cross:
        specs["gate"] = ((1,), 0)  # tanh-gated cross-attn (llama3.2-vision)
    return specs


def init_params(key, specs, dtype):
    params = {}
    for i, (name, (shape, in_dim)) in enumerate(sorted(specs.items())):
        k = jax.random.fold_in(key, i)
        if in_dim == -1:   # norm weight
            params[name] = jnp.ones(shape, dtype)
        elif in_dim == 0:  # bias / gate
            params[name] = jnp.zeros(shape, dtype)
        else:
            params[name] = dense_init(k, shape, in_dim, dtype)
    return params


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def _project_qkv(ctx, cfg, dims, p, x, xkv, positions, kv_positions=None,
                 use_rope=True):
    """x: (B,S,d) -> q (B,S,Hl,hd); k,v (B,Skv,KV,hd) (kv replicated)."""
    B, S, _ = x.shape
    hd = dims.head_dim
    q = x @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, dims.heads_local, hd)
    k = k.reshape(B, xkv.shape[1], dims.n_kv_heads, hd)
    v = v.reshape(B, xkv.shape[1], dims.n_kv_heads, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        kpos = positions if kv_positions is None else kv_positions
        k = rope(k, kpos, cfg.rope_theta)
    return q, k, v


def _expand_kv(ctx, dims: Dims, cfg: ModelConfig, k, v):
    """kv (B,S,KV,hd) -> one kv head per *local* q head (gather)."""
    ratio = max(1, cfg.num_heads // cfg.num_kv_heads)
    global_q = ctx.tp_rank() * dims.heads_local + jnp.arange(dims.heads_local)
    idx = jnp.minimum(global_q // ratio, dims.n_kv_heads - 1)
    return jnp.take(k, idx, axis=2), jnp.take(v, idx, axis=2)


def _expand_kv_all_heads(cfg: ModelConfig, dims: Dims, k, v):
    """kv (B,S,KV,hd) -> one kv head per *global* (padded) q head."""
    ratio = max(1, cfg.num_heads // cfg.num_kv_heads)
    idx = jnp.minimum(jnp.arange(dims.n_heads) // ratio,
                      dims.n_kv_heads - 1)
    return jnp.take(k, idx, axis=2), jnp.take(v, idx, axis=2)


# ---------------------------------------------------------------------------
# flash-style attention (train / prefill)
# ---------------------------------------------------------------------------

MAX_Q_BLOCKS = 32


def _flash(q, k, v, *, causal: bool, window: int, q_block: int, kv_block: int):
    """q: (B,S,H,hd); k,v: (B,Skv,H,hd) head-expanded. window<=0: unlimited.

    Static Python loop over query blocks (bounded to MAX_Q_BLOCKS so HLO
    stays O(32) regardless of S); per q block a ``lax.scan`` over exactly
    the kv blocks the causal/window structure admits — bounds are static,
    so FLOPs are exact *and* the whole thing is reverse-differentiable.
    """
    B, S, H, hd = q.shape
    Skv = k.shape[1]
    q_block = min(max(q_block, -(-S // MAX_Q_BLOCKS)), S)
    while S % q_block:
        q_block += 1
    kv_block = min(kv_block, Skv)
    if Skv % kv_block:
        # e.g. cross-attention over 1601 image tokens: fall back to a
        # single kv block (non-power-of-two kv extents are small in
        # practice — modality frontends)
        kv_block = Skv
    nq = S // q_block
    nkv = Skv // kv_block
    scale = hd ** -0.5
    qt = q.transpose(0, 2, 1, 3).astype(jnp.float32) * scale  # (B,H,S,hd)
    kt = k.transpose(0, 2, 1, 3).astype(jnp.float32)
    vt = v.transpose(0, 2, 1, 3).astype(jnp.float32)

    outs = []
    for qi in range(nq):
        q_start = qi * q_block
        qb = jax.lax.slice_in_dim(qt, q_start, q_start + q_block, axis=2)

        hi = min(-(-(q_start + q_block) // kv_block), nkv) if causal else nkv
        lo = max((q_start - window) // kv_block, 0) if window > 0 else 0

        m0 = jnp.full((B, H, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_block), jnp.float32)
        a0 = jnp.zeros((B, H, q_block, hd), jnp.float32)

        def kv_step(carry, ki, q_start=q_start, qb=qb):
            m, l, acc = carry
            k_start = ki * kv_block
            kb = jax.lax.dynamic_slice_in_dim(kt, k_start, kv_block, axis=2)
            vb = jax.lax.dynamic_slice_in_dim(vt, k_start, kv_block, axis=2)
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb)
            qpos = q_start + jnp.arange(q_block)
            kpos = k_start + jnp.arange(kv_block)
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vb)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), jnp.arange(lo, hi))
        outs.append(acc / jnp.maximum(l, 1e-30)[..., None])

    out = jnp.concatenate(outs, axis=2)  # (B,H,S,hd)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attn_forward(
    ctx: TPCtx,
    cfg: ModelConfig,
    dims: Dims,
    p,
    x,
    positions,
    kind: str,
    *,
    return_cache: bool = False,
    max_len: int = 0,
    cache_shards: int = 1,
    seq_shard_axes: tuple = ("model",),
    q_block: int = 512,
    kv_block: int = 512,
):
    """Self-attention for train/prefill. Returns (out, cache | None).

    cache: (k_shard, v_shard) — this device's slice of the ring-addressed
    decode cache (C = max_len for FULL, window/chunk otherwise; slot =
    position % C, shard slot // C_local owns it), RoPE already applied,
    layout (B, C_local, KV, hd).  Matches attn_decode's addressing.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(ctx, cfg, dims, p, x, x, positions)
    ke, ve = _expand_kv(ctx, dims, cfg, k, v)

    if kind == CHUNKED and S > cfg.chunk:
        c = cfg.chunk
        n_full = S // c
        body_len = n_full * c

        def fold(t):
            return t.reshape(B * n_full, c, *t.shape[2:])

        out = _flash(fold(q[:, :body_len]), fold(ke[:, :body_len]),
                     fold(ve[:, :body_len]), causal=True, window=0,
                     q_block=q_block, kv_block=kv_block)
        out = out.reshape(B, body_len, dims.heads_local, dims.head_dim)
        if body_len < S:  # trailing partial chunk (its own causal block)
            tail = _flash(q[:, body_len:], ke[:, body_len:], ve[:, body_len:],
                          causal=True, window=0, q_block=q_block,
                          kv_block=kv_block)
            out = jnp.concatenate([out, tail], axis=1)
    else:
        window = cfg.window if kind == SLIDING else 0
        out = _flash(q, ke, ve, causal=True, window=window,
                     q_block=q_block, kv_block=kv_block)

    out = out * head_mask(ctx, cfg, dims)[None, None, :, None].astype(out.dtype)
    y = ctx.psum_tp(out.reshape(B, S, -1) @ p["wo"])

    cache = None
    if return_cache:
        C, C_local = cache_spec(cfg, dims, kind, max_len or S, cache_shards)
        shard_id = jnp.zeros((), jnp.int32)
        for ax in seq_shard_axes:
            shard_id = shard_id * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
        keep = min(C, S)
        t = jnp.arange(S - keep, S)
        slot = t % C
        owner = slot // C_local
        mine = owner == shard_id
        local_slot = jnp.where(mine, slot % C_local, C_local)  # OOB -> drop
        kk = jnp.zeros((B, C_local, dims.n_kv_heads, dims.head_dim), k.dtype)
        vv = jnp.zeros_like(kk)
        kk = kk.at[:, local_slot].set(k[:, S - keep:], mode="drop")
        vv = vv.at[:, local_slot].set(v[:, S - keep:], mode="drop")
        cache = (kk, vv)
    return y, cache


def cross_attn_forward(ctx, cfg, dims, p, x, vision_states):
    """Gated cross-attention against (B, S_img, d) vision embeddings."""
    B, S, _ = x.shape
    pos = jnp.zeros((B, S), jnp.int32)
    q, k, v = _project_qkv(ctx, cfg, dims, p, x, vision_states, pos,
                           use_rope=False)
    ke, ve = _expand_kv(ctx, dims, cfg, k, v)
    out = _flash(q, ke, ve, causal=False, window=0, q_block=512, kv_block=512)
    out = out * head_mask(ctx, cfg, dims)[None, None, :, None].astype(out.dtype)
    y = ctx.psum_tp(out.reshape(B, S, -1) @ p["wo"])
    return jnp.tanh(p["gate"]).astype(y.dtype) * y


# ---------------------------------------------------------------------------
# decode: one token vs a sequence-sharded cache
# ---------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, dims: Dims, kind: str, max_len: int,
               shards: int):
    """(C_global, C_local) cache slots for one attention layer."""
    if kind == SLIDING:
        C = min(cfg.window, max_len)
    elif kind == CHUNKED:
        C = min(cfg.chunk, max_len)
    else:
        C = max_len
    C = -(-C // shards) * shards
    return C, C // shards


def attn_decode(
    ctx: TPCtx,
    cfg: ModelConfig,
    dims: Dims,
    p,
    x,            # (B, 1, d)
    pos,          # (B,) absolute position of the new token
    cache,        # (k, v): (B, C_local, KV, hd) this device's seq shard
    kind: str,
    *,
    cache_shards: int,
    seq_shard_axes: tuple = ("model",),
):
    """One-token decode. Returns (out (B,1,d), new (k,v) cache shards).

    The global cache has C = C_local * cache_shards slots, ring-addressed
    by ``slot = pos % C``; shard ``slot // C_local`` owns the write.
    Validity and (for sliding/chunked) window masks are evaluated from the
    absolute position each slot last stored.
    """
    B = x.shape[0]
    hd = dims.head_dim
    q, k_new, v_new = _project_qkv(ctx, cfg, dims, p, x, x, pos[:, None])
    # q heads are TP-sharded but the cache is *sequence*-sharded over the
    # same axis: gather the (tiny) decode q so every rank evaluates ALL
    # heads against its sequence shard; the psum below then combines
    # pure sequence-partial stats.  Local heads are sliced back before
    # the row-parallel output projection.
    q = jax.lax.all_gather(q, ctx.model_axis, axis=2, tiled=True)
    H = q.shape[2]  # padded global head count
    k_cache, v_cache = cache
    C_local = k_cache.shape[1]
    C = C_local * cache_shards

    # --- shard id along the sequence sharding axes ---
    shard_id = jnp.zeros((), jnp.int32)
    for ax in seq_shard_axes:
        shard_id = shard_id * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)

    # --- write the new token into its ring slot (owner shard only) ---
    slot = (pos % C).astype(jnp.int32)              # (B,)
    owner = slot // C_local
    local_slot = slot % C_local
    is_mine = (owner == shard_id)[:, None, None]
    bidx = jnp.arange(B)
    k_upd = k_cache.at[bidx, local_slot].set(
        jnp.where(is_mine, k_new[:, 0], k_cache[bidx, local_slot]))
    v_upd = v_cache.at[bidx, local_slot].set(
        jnp.where(is_mine, v_new[:, 0], v_cache[bidx, local_slot]))

    # --- absolute position stored in each local slot (post-write) ---
    gslot = shard_id * C_local + jnp.arange(C_local)          # (C_local,)
    delta = (pos[:, None] % C) - gslot[None, :]
    delta = jnp.where(delta < 0, delta + C, delta)
    slot_pos = pos[:, None] - delta                            # (B, C_local)
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if kind == SLIDING:
        valid &= slot_pos > pos[:, None] - cfg.window
    elif kind == CHUNKED:
        valid &= slot_pos >= (pos[:, None] // cfg.chunk) * cfg.chunk
    # exclude the just-written slot from the shard pass; the new token is
    # folded in exactly once below.
    valid &= slot_pos != pos[:, None]

    ke, ve = _expand_kv_all_heads(cfg, dims, k_upd, v_upd)     # (B,Cl,H,hd)
    s = jnp.einsum("bqhd,bchd->bhqc", q.astype(jnp.float32) * hd ** -0.5,
                   ke.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)

    m = jnp.max(s, axis=-1)                                    # (B,H,1)
    for ax in seq_shard_axes:
        m = jax.lax.pmax(m, ax)
    ps = jnp.exp(s - m[..., None])
    l = jnp.sum(ps, axis=-1)
    acc = jnp.einsum("bhqc,bchd->bhqd", ps, ve.astype(jnp.float32))
    for ax in seq_shard_axes:
        l = jax.lax.psum(l, ax)
        acc = jax.lax.psum(acc, ax)

    # fold in the new token's own (k, v) — always visible to itself
    ke_new, ve_new = _expand_kv_all_heads(cfg, dims, k_new, v_new)
    s_new = jnp.einsum("bqhd,bqhd->bhq", q.astype(jnp.float32) * hd ** -0.5,
                       ke_new.astype(jnp.float32))
    m2 = jnp.maximum(m, s_new)
    corr = jnp.exp(m - m2)
    pn = jnp.exp(s_new - m2)
    l2 = l * corr + pn
    acc2 = acc * corr[..., None] + pn[..., None] * ve_new.astype(
        jnp.float32).transpose(0, 2, 1, 3)
    out = (acc2 / jnp.maximum(l2, 1e-30)[..., None]).transpose(0, 2, 1, 3)

    # back to this rank's local heads for the row-parallel output proj
    start = ctx.tp_rank() * dims.heads_local
    out = jax.lax.dynamic_slice_in_dim(out, start, dims.heads_local, axis=2)
    out = out * head_mask(ctx, cfg, dims)[None, None, :, None]
    y = ctx.psum_tp(out.reshape(B, 1, -1).astype(x.dtype) @ p["wo"])
    return y, (k_upd, v_upd)

"""Model configuration covering all six assigned architecture families.

One ``ModelConfig`` describes dense / MoE / SSM (RWKV6, Mamba) / hybrid /
VLM / audio decoder stacks.  Layer heterogeneity (Jamba's 1:7
attn:mamba interleave, Llama-3.2-Vision's cross-attention every 5th
layer, Llama-4's chunked-attention 3:1 pattern, Jamba's MoE-every-other
layer) is expressed as a repeating *group* of ``group_size`` layer slots;
the whole stack is ``num_layers // group_size`` repetitions of that group
and is executed with one ``lax.scan`` over stacked group parameters (so
HLO size is O(group), not O(layers)).
"""
from __future__ import annotations

import dataclasses
import math

# layer-slot kinds
ATTN = "attn"
MAMBA = "mamba"
RWKV = "rwkv"
CROSS = "cross"  # cross-attention (VLM) — always paired with self-attn slot

# attention kinds
FULL = "full"
SLIDING = "sliding"
CHUNKED = "chunked"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str            # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // num_heads

    # attention flavour
    attn_kind: str = FULL     # full | sliding | chunked
    window: int = 4096        # sliding-window size
    chunk: int = 8192         # chunked-attention chunk
    full_attn_every: int = 0  # >0: every k-th attn layer is FULL (llama4 iRoPE)
    qk_norm: bool = False     # qwen3
    qkv_bias: bool = False    # qwen1.5
    rope_theta: float = 1e6

    # mixture of experts
    moe: bool = False
    num_experts: int = 0
    top_k: int = 2
    moe_every: int = 1        # MoE FFN on every k-th layer (jamba: 2)
    shared_expert: bool = False  # llama4
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # hybrid / ssm
    layer_pattern: str = ATTN  # attn | rwkv | mamba_hybrid
    attn_every: int = 0        # hybrid: attention slot every k-th layer (jamba: 8)
    mamba_d_state: int = 16
    mamba_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0     # 0 -> ceil(d_model / 16)
    rwkv_head_dim: int = 64

    # vlm
    cross_attn_every: int = 0  # self-attn layers per cross-attn layer (llama3.2: 5)
    num_image_tokens: int = 1601

    # numerics
    norm_eps: float = 1e-5
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # source citation (public pool requirement)
    source: str = ""

    # ----- derived ------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    @property
    def group_size(self) -> int:
        """Length of the repeating layer pattern."""
        g = 1
        if self.attn_every:
            g = math.lcm(g, self.attn_every)
        if self.cross_attn_every:
            g = math.lcm(g, self.cross_attn_every)
        if self.moe and self.moe_every > 1:
            g = math.lcm(g, self.moe_every)
        if self.full_attn_every:
            g = math.lcm(g, self.full_attn_every)
        return g

    @property
    def num_groups(self) -> int:
        if self.num_layers % self.group_size:
            raise ValueError(
                f"{self.name}: num_layers {self.num_layers} not divisible by "
                f"group_size {self.group_size}"
            )
        return self.num_layers // self.group_size

    def slot_kind(self, slot: int) -> str:
        """Mixer kind for layer-slot `slot` within a group."""
        if self.layer_pattern == RWKV:
            return RWKV
        if self.layer_pattern == "mamba_hybrid":
            # jamba: one attention layer per `attn_every` layers, rest mamba
            return ATTN if (slot % self.attn_every == self.attn_every - 1) else MAMBA
        return ATTN

    def slot_has_cross(self, slot: int) -> bool:
        if not self.cross_attn_every:
            return False
        return slot % self.cross_attn_every == self.cross_attn_every - 1

    def slot_is_moe(self, slot: int) -> bool:
        if not self.moe:
            return False
        return slot % self.moe_every == self.moe_every - 1

    def slot_attn_kind(self, slot: int) -> str:
        if self.full_attn_every:
            return FULL if (slot % self.full_attn_every == self.full_attn_every - 1) else self.attn_kind
        return self.attn_kind

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch hold a 500k context (long_500k eligibility)?"""
        if self.layer_pattern in (RWKV, "mamba_hybrid"):
            return True  # O(1)/chunked state; hybrid attn layers are seq-sharded
        return self.attn_kind in (SLIDING, CHUNKED)

    def param_count(self) -> int:
        """Approximate global parameter count (unpadded)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim_
        n_q = self.num_heads * hd
        n_kv = self.num_kv_heads * hd
        total = 2 * V * d  # embed + lm head
        for slot in [g for g in range(self.group_size)]:
            kind = self.slot_kind(slot)
            if kind == ATTN:
                mix = d * n_q + 2 * d * n_kv + n_q * d
            elif kind == RWKV:
                mix = 6 * d * d  # r,k,v,g,w(+lora),o approx
            else:  # mamba
                di = self.mamba_expand * d
                mix = 2 * d * di + di * d + di * (2 * self.mamba_d_state + self.dt_rank)
            if self.slot_has_cross(slot):
                mix += d * n_q + 2 * d * n_kv + n_q * d
            if self.slot_is_moe(slot):
                ffp = self.num_experts * 3 * d * ff
                if self.shared_expert:
                    ffp += 3 * d * ff
            else:
                ffp = 3 * d * ff
            total += (mix + ffp) * self.num_groups
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared only)."""
        if not self.moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_like = self.param_count()
        for slot in range(self.group_size):
            if self.slot_is_moe(slot):
                unused = (self.num_experts - self.top_k) * 3 * d * ff
                dense_like -= unused * self.num_groups
        return dense_like

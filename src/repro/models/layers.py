"""Tensor-parallel primitive layers (manual collectives).

All model code executes *inside* ``jax.shard_map`` with the mesh axes
manual, so tensor parallelism is written explicitly:

  * column-parallel matmul: weight sharded on its output dim over the
    ``model`` axis; no collective (activations replicated in).
  * row-parallel matmul: weight sharded on its input dim; partial outputs
    summed with ``psum(axis='model')``.
  * vocab-parallel embedding / LM head with psum-combined lookup and a
    distributed (max/logsumexp) softmax cross-entropy.

Head / ffn / vocab dims are zero-padded up to multiples of the TP degree
(``Dims``); padding columns are initialized to zero and contribute
nothing (their gradients stay zero under SGD, and the LM-head padding is
masked to -inf in the softmax).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig


def pad_to(n: int, m: int) -> int:
    return -(-n // m) * m


class TPCtx(NamedTuple):
    """Static sharding context threaded through model code."""

    model_axis: str = "model"
    data_axes: tuple = ("data",)
    tp: int = 1
    dp: int = 1
    compute_dtype: jnp.dtype = jnp.bfloat16

    def psum_tp(self, x):
        # named so remat policies can pin collective outputs as residuals
        # (remat="dots_psum"): replaying a psum in the backward costs real
        # ICI bandwidth, unlike replaying elementwise compute.
        from jax.ad_checkpoint import checkpoint_name
        return checkpoint_name(
            jax.lax.psum(x, self.model_axis), "tp_psum")

    def tp_rank(self):
        return jax.lax.axis_index(self.model_axis)


class Dims(NamedTuple):
    """TP-padded local dimensions for one config.

    Query heads are zero-pad-sharded over the model axis; the (small,
    GQA) kv projection is *replicated* across it — this keeps every q
    head's kv head device-local for any (heads, kv, tp) combination, at
    the cost of replicating the cheap kv-proj FLOPs.  Decode KV caches
    are sharded over the model axis along the *sequence* dim instead
    (attention.py combines partial softmax stats with pmax/psum).
    """

    n_heads: int          # padded global query heads
    n_kv_heads: int       # kv heads (replicated; unpadded)
    heads_local: int
    d_ff: int             # padded global
    ff_local: int
    vocab: int            # padded global
    vocab_local: int
    head_dim: int
    tp: int

    @property
    def heads_unpadded_ratio(self) -> float:
        return 1.0


def make_dims(cfg: ModelConfig, tp: int) -> Dims:
    hd = cfg.head_dim_
    n_heads = pad_to(cfg.num_heads, tp)
    d_ff = pad_to(cfg.d_ff, tp)
    vocab = pad_to(cfg.vocab_size, tp)
    return Dims(
        n_heads=n_heads,
        n_kv_heads=cfg.num_kv_heads,
        heads_local=n_heads // tp,
        d_ff=d_ff,
        ff_local=d_ff // tp,
        vocab=vocab,
        vocab_local=vocab // tp,
        head_dim=hd,
        tp=tp,
    )


def head_mask(ctx: "TPCtx", cfg: ModelConfig, dims: Dims):
    """1.0 for real q heads, 0.0 for TP padding heads (keeps padded
    weights at zero gradient so they never contaminate the output)."""
    g = ctx.tp_rank() * dims.heads_local + jnp.arange(dims.heads_local)
    return (g < cfg.num_heads).astype(jnp.float32)


# ---------------------------------------------------------------------------
# initializers — all take the *local* shape; padding handled by callers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_dim, dtype):
    scale = in_dim ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * weight


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# vocab-parallel embedding / head / loss
# ---------------------------------------------------------------------------

def embed_lookup(ctx: TPCtx, emb_local, ids):
    """emb_local: (vocab_local, d); ids: (B, S) global ids."""
    vloc = emb_local.shape[0]
    start = ctx.tp_rank() * vloc
    local = ids - start
    inside = (local >= 0) & (local < vloc)
    safe = jnp.clip(local, 0, vloc - 1)
    x = jnp.take(emb_local, safe, axis=0)
    x = jnp.where(inside[..., None], x, 0.0)
    return ctx.psum_tp(x.astype(ctx.compute_dtype))


def _ce_chunk(ctx: TPCtx, w_local, x, labels, vocab_unpadded: int):
    """CE loss-sum for one (B, chunk, d) slice; vocab-sharded softmax."""
    vloc = w_local.shape[-1]
    start = ctx.tp_rank() * vloc
    logits = (x @ w_local).astype(jnp.float32)  # (B,C,vloc)
    col = start + jnp.arange(vloc)
    logits = jnp.where(col[None, None, :] < vocab_unpadded, logits, -jnp.inf)

    m_local = jnp.max(logits, axis=-1)
    # pmax has no AD rule; all_gather+max is differentiable (and the max
    # is a constant shift anyway, so stop_gradient keeps the exact grad).
    m_all = jax.lax.all_gather(jax.lax.stop_gradient(m_local),
                               ctx.model_axis)
    m = jnp.max(m_all, axis=0)
    se = ctx.psum_tp(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
    lse = m + jnp.log(se)

    local_label = labels - start
    inside = (local_label >= 0) & (local_label < vloc)
    safe = jnp.clip(local_label, 0, vloc - 1)
    picked = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    correct = ctx.psum_tp(jnp.where(inside, picked, 0.0))
    return jnp.sum(lse - correct)


def lm_head_loss(ctx: TPCtx, w_local, x, labels, vocab_unpadded: int,
                 chunk: int = 512):
    """Distributed softmax cross-entropy over a vocab-sharded LM head.

    Computed over sequence chunks (rematerialized) so the f32 logits temp
    is (B, chunk, vocab_local) rather than the full sequence.
    w_local: (d, vocab_local); x: (B, S, d); labels: (B, S).
    Returns mean CE loss over all positions.
    """
    B, S, d = x.shape
    if S <= chunk or S % chunk:
        return _ce_chunk(ctx, w_local, x, labels, vocab_unpadded) / (B * S)

    nc = S // chunk

    def body(acc, inp):
        xc, lc = inp
        return acc + _ce_chunk(ctx, w_local, xc, lc, vocab_unpadded), None

    body = jax.checkpoint(body)
    xs = (x.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3),
          labels.reshape(B, nc, chunk).transpose(1, 0, 2))
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
    return total / (B * S)


def ffn_param_specs(cfg: ModelConfig, dims: Dims):
    d = cfg.d_model
    return {
        "w1": ((d, dims.ff_local), d),
        "w3": ((d, dims.ff_local), d),
        "w2": ((dims.ff_local, d), dims.d_ff),
    }


def ffn_forward(ctx: TPCtx, p, x):
    """SwiGLU FFN, column->row parallel with one psum."""
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    return ctx.psum_tp(h @ p["w2"])


def lm_head_logits(ctx: TPCtx, w_local, x, vocab_unpadded: int):
    """Full (all-gathered) logits for serving; x: (B, d) last-position."""
    logits = (x @ w_local).astype(jnp.float32)
    full = jax.lax.all_gather(
        logits, ctx.model_axis, axis=logits.ndim - 1, tiled=True
    )
    return full[..., :vocab_unpadded]

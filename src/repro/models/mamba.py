"""Mamba selective-SSM layer (Jamba's sequence mixer).

Diagonal selective state space:
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t
    y_t = C_t . h_t + D * x_t

TPU adaptation: the diagonal recurrence is associative, so each chunk of
64 steps runs as a ``jax.lax.associative_scan`` (log-depth, vectorized
over channels/state) while an outer ``lax.scan`` carries the (d_inner,
d_state) state across chunks — bounding the unrolled working set to one
chunk.  The channel dimension (d_inner = expand * d_model) is sharded
over the ``model`` axis; out_proj is row-parallel (psum).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Dims, TPCtx, dense_init

MAMBA_CHUNK = 64


def mamba_dims(cfg: ModelConfig, tp: int):
    di = cfg.mamba_expand * cfg.d_model
    assert di % tp == 0, (cfg.name, di, tp)
    return di, di // tp


def mamba_param_specs(cfg: ModelConfig, dims: Dims, tp: int):
    d = cfg.d_model
    di, dil = mamba_dims(cfg, tp)
    st, rk, cw = cfg.mamba_d_state, cfg.dt_rank, cfg.mamba_conv
    return {
        "in_proj": ((d, 2 * dil), d),
        "conv_w": ((cw, dil), 0),
        "conv_b": ((dil,), 0),
        "x_proj": ((dil, rk + 2 * st), dil),
        "dt_proj": ((rk, dil), rk),
        "dt_bias": ((dil,), 0),
        "A_log": ((dil, st), -2),   # special init
        "D": ((dil,), -1),
        "out_proj": ((dil, d), di),
    }


def _causal_conv(x, w, b, width: int, conv_state=None):
    """Depthwise causal conv along S. x: (B,S,dil); w: (width, dil)."""
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)
    y = jnp.zeros_like(x) + b
    for j in range(width):
        y = y + w[j] * jax.lax.dynamic_slice_in_dim(
            xp, j, x.shape[1], axis=1)
    new_state = xp[:, -(width - 1):] if width > 1 else pad
    return y, new_state


def _ssm_scan(decay, drive, h0, chunk: int):
    """h_t = decay_t * h_{t-1} + drive_t, both (B,S,dil,st); h0 (B,dil,st)."""
    B, S, dil, st = decay.shape
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L

    def chunk_step(h, inp):
        dc, dr = inp  # (B,L,dil,st)

        def combine(a, b):
            return (a[0] * b[0], a[1] * b[0] + b[1])

        cd, ch = jax.lax.associative_scan(combine, (dc, dr), axis=1)
        hs = cd * h[:, None] + ch           # states for every step
        return hs[:, -1], hs

    def split(t):
        return t.reshape(B, nc, L, dil, st).transpose(1, 0, 2, 3, 4)

    h_last, hs = jax.lax.scan(chunk_step, h0, (split(decay), split(drive)))
    return h_last, hs.transpose(1, 0, 2, 3, 4).reshape(B, S, dil, st)


def mamba_forward(ctx: TPCtx, cfg: ModelConfig, dims: Dims, p, x, *,
                  cache=None, return_state=False, chunk: int = MAMBA_CHUNK):
    """x: (B,S,d). cache = (h (B,dil,st), conv_state (B,width-1,dil))."""
    B, S, d = x.shape
    di, dil = mamba_dims(cfg, ctx.tp)
    st, rk, cw = cfg.mamba_d_state, cfg.dt_rank, cfg.mamba_conv

    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)          # (B,S,dil) each
    conv_state = cache[1] if cache is not None else None
    xc, new_conv = _causal_conv(xin, p["conv_w"], p["conv_b"], cw, conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32))

    proj = xc @ p["x_proj"].astype(jnp.float32)
    dt_raw, Bs, Cs = jnp.split(proj, [rk, rk + st], axis=-1)
    dt = jax.nn.softplus(dt_raw @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,S,dil)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (dil,st)

    decay = jnp.exp(dt[..., None] * A)                          # (B,S,dil,st)
    drive = (dt * xc)[..., None] * Bs[:, :, None, :]

    h0 = cache[0] if cache is not None else jnp.zeros((B, dil, st), jnp.float32)
    h_last, hs = _ssm_scan(decay, drive, h0, chunk)

    y = jnp.einsum("bsdn,bsn->bsd", hs, Cs)
    y = y + p["D"].astype(jnp.float32) * xc
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = ctx.psum_tp(y.astype(x.dtype) @ p["out_proj"])
    if return_state:
        return out, (h_last, new_conv)
    return out, None


def mamba_decode(ctx: TPCtx, cfg: ModelConfig, dims: Dims, p, x, cache):
    """Single-token step; x: (B,1,d)."""
    out, new_cache = mamba_forward(
        ctx, cfg, dims, p, x, cache=cache, return_state=True, chunk=1)
    return out, new_cache

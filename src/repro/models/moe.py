"""Mixture-of-Experts FFN with capacity-based routing and expert+tensor
parallelism over the ``model`` mesh axis.

The TP degree is factored as tp = ep * fp with ep = gcd(num_experts, tp):
device r owns expert block r // fp and ffn shard r % fp.  Tokens stay
resident (they are replicated across the model axis between blocks), each
device computes its local experts' contribution at capacity, and a single
``psum('model')`` combines both the expert dimension and the row-parallel
ffn partial sums — the same collective the dense row-parallel FFN needs,
so MoE adds *no* extra collectives beyond the router's negligible cost.
Dropped-beyond-capacity tokens fall through with zero contribution
(standard GShard/Switch semantics; capacity_factor controls the drop
rate).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Dims, TPCtx, dense_init


def moe_factor(cfg: ModelConfig, tp: int) -> tuple[int, int]:
    ep = math.gcd(cfg.num_experts, tp)
    return ep, tp // ep


def moe_param_specs(cfg: ModelConfig, dims: Dims, tp: int):
    d = cfg.d_model
    ep, fp = moe_factor(cfg, tp)
    e_local = cfg.num_experts // ep
    ff_local = -(-cfg.d_ff // fp)
    specs = {
        "router": ((d, cfg.num_experts), d),
        "w1": ((e_local, d, ff_local), d),
        "w3": ((e_local, d, ff_local), d),
        "w2": ((e_local, ff_local, d), cfg.d_ff),
    }
    if cfg.shared_expert:
        specs["sw1"] = ((d, dims.ff_local), d)
        specs["sw3"] = ((d, dims.ff_local), d)
        specs["sw2"] = ((dims.ff_local, d), cfg.d_ff)
    return specs


def init_moe_params(key, specs, dtype):
    out = {}
    for i, (name, (shape, in_dim)) in enumerate(sorted(specs.items())):
        out[name] = dense_init(jax.random.fold_in(key, i), shape, in_dim, dtype)
    return out


def capacity(cfg: ModelConfig, num_tokens: int) -> int:
    c = int(math.ceil(num_tokens * cfg.top_k / cfg.num_experts
                      * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)


def moe_ffn(ctx: TPCtx, cfg: ModelConfig, p, x):
    """x: (B, S, d) replicated over model axis -> (B, S, d), aux loss."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E, k = cfg.num_experts, cfg.top_k
    ep, fp = moe_factor(cfg, tp=ctx.tp)
    e_local = E // ep
    C = capacity(cfg, T)

    # ---- routing (replicated compute; router weights replicated) --------
    logits = (xt @ p["router"]).astype(jnp.float32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, k)                # (T, k)
    if k > 1:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert.reshape(-1)].add(
        1.0 / (T * k), mode="promise_in_bounds")
    aux = E * jnp.sum(me * ce) * cfg.router_aux_coef

    # ---- dispatch: position of each (token, slot) within its expert -----
    flat_e = expert.reshape(-1)                           # (T*k,) token-major
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)   # (T*k, E)
    pos = (jnp.cumsum(onehot, axis=0) - 1) * onehot       # rank within expert
    pos = jnp.sum(pos, axis=-1)                           # (T*k,)
    keep = pos < C

    my_block = ctx.tp_rank() // fp                        # expert block id
    e_lo = my_block * e_local
    mine = (flat_e >= e_lo) & (flat_e < e_lo + e_local) & keep
    e_loc = jnp.clip(flat_e - e_lo, 0, e_local - 1)
    tok = jnp.arange(T * k) // k

    expert_in = jnp.zeros((e_local, C, d), x.dtype)
    expert_in = expert_in.at[
        jnp.where(mine, e_loc, 0), jnp.where(mine, pos, 0)
    ].add(jnp.where(mine[:, None], xt[tok], 0))

    # ---- expert computation (ffn shard fp-way row/col parallel) ----------
    h = jnp.einsum("ecd,edf->ecf", expert_in, p["w1"])
    g = jnp.einsum("ecd,edf->ecf", expert_in, p["w3"])
    h = jax.nn.silu(h) * g
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w2"])   # partial over fp

    # ---- combine: gather back, weight by gate, psum over model -----------
    contrib = expert_out[
        jnp.where(mine, e_loc, 0), jnp.where(mine, pos, 0)
    ]                                                      # (T*k, d)
    contrib = jnp.where(mine[:, None], contrib, 0)
    gflat = gate.reshape(-1).astype(contrib.dtype)
    y = jnp.zeros((T, d), contrib.dtype).at[tok].add(contrib * gflat[:, None])
    # replicated expert blocks (fp > 1) each add their ffn partial sums;
    # expert blocks are disjoint -> one psum merges everything.
    if cfg.shared_expert:
        sh = jax.nn.silu(xt @ p["sw1"]) * (xt @ p["sw3"])
        y = y + sh @ p["sw2"]
    y = ctx.psum_tp(y)
    return y.reshape(B, S, d).astype(x.dtype), aux

"""RWKV6 ("Finch") time-mix layer — attention-free, data-dependent decay.

Recurrence per head (state S in R^{hd x hd}):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          w_t = exp(-exp(.)) in (0,1)
    o_t = r_t S_{t-1} + (r_t . (u ⊙ k_t)) v_t    (u = per-channel bonus)

TPU adaptation: the sequential recurrence is rewritten as *chunked linear
attention* — within a chunk of length L the contribution of step i<t is
an exact masked matmul weighted by exp(cw_{t-1} - cw_i) (cw = cumulative
log-decay, so every exponent is <= 0: numerically safe without clamping);
across chunks a ``lax.scan`` carries the (hd x hd) state.  This turns the
recurrence into MXU-shaped einsums with an O(L^2 · hd) working set per
chunk instead of an O(S) serial chain.

Heads are sharded over the ``model`` axis; the output projection is
row-parallel (psum).  The decay is data-dependent through a LoRA on the
token-shifted input (the defining RWKV6 feature); r/k/v/g use learned
static token-shift interpolation (the dynamic ddlerp is applied to the
decay path, where the paper's adaptivity lives — noted in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Dims, TPCtx, dense_init

LORA_DIM = 64
RWKV_CHUNK = 32


def rwkv_dims(cfg: ModelConfig, tp: int):
    hd = cfg.rwkv_head_dim
    n_heads = cfg.d_model // hd
    assert n_heads % tp == 0, (cfg.name, n_heads, tp)
    return n_heads, n_heads // tp, hd


def rwkv_param_specs(cfg: ModelConfig, dims: Dims, tp: int):
    d = cfg.d_model
    _, h_local, hd = rwkv_dims(cfg, tp)
    dl = h_local * hd
    return {
        "mu_r": ((d,), 0), "mu_k": ((d,), 0), "mu_v": ((d,), 0),
        "mu_g": ((d,), 0), "mu_w": ((d,), 0),
        "w0": ((d,), 0),
        "w_lora_a": ((d, LORA_DIM), d),
        "w_lora_b": ((LORA_DIM, d), LORA_DIM),
        "proj_r": ((d, dl), d), "proj_k": ((d, dl), d), "proj_v": ((d, dl), d),
        "proj_g": ((d, dl), d),
        "u": ((dl,), 0),
        "ln_x": ((dl,), -1),
        "wo": ((dl, d), d),
    }


def _token_shift(x, prev):
    """x: (B,S,d); prev: (B,1,d) last token of the previous segment."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def _decay_log(ctx, p, xw, h_local, hd):
    """Data-dependent per-channel log decay, in (-inf, 0), sliced to this
    device's head block (the LoRA targets all d channels)."""
    lora = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    full = -jnp.exp(
        jnp.clip((p["w0"] + lora).astype(jnp.float32), -8.0, 8.0)
    )  # <= 0 always
    dl = h_local * hd
    start = ctx.tp_rank() * dl
    return jax.lax.dynamic_slice_in_dim(full, start, dl, axis=-1)


def _group_rms(x, weight, eps):
    """Per-head RMS norm on (B,S,H,hd)-flattened channels."""
    B, S, H, hd = x.shape
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y.reshape(B, S, H * hd) * weight).astype(x.dtype)


def rwkv_forward(ctx: TPCtx, cfg: ModelConfig, dims: Dims, p, x, *,
                 prev_token=None, state=None, return_state=False,
                 chunk: int = RWKV_CHUNK):
    """x: (B,S,d) -> (B,S,d). state: (B,Hl,hd,hd); prev_token: (B,1,d)."""
    B, S, d = x.shape
    _, Hl, hd = rwkv_dims(cfg, ctx.tp)
    if prev_token is None:
        prev_token = jnp.zeros((B, 1, d), x.dtype)
    xs = _token_shift(x, prev_token)

    r = (_mix(x, xs, p["mu_r"]) @ p["proj_r"]).reshape(B, S, Hl, hd)
    k = (_mix(x, xs, p["mu_k"]) @ p["proj_k"]).reshape(B, S, Hl, hd)
    v = (_mix(x, xs, p["mu_v"]) @ p["proj_v"]).reshape(B, S, Hl, hd)
    g = _mix(x, xs, p["mu_g"]) @ p["proj_g"]
    logw = _decay_log(ctx, p, _mix(x, xs, p["mu_w"]), Hl, hd).reshape(
        B, S, Hl, hd)
    u = p["u"].reshape(Hl, hd).astype(jnp.float32)

    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))

    if state is None:
        state = jnp.zeros((B, Hl, hd, hd), jnp.float32)

    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    def chunk_step(S0, inp):
        rc, kc, vc, wc = inp  # (B,L,Hl,hd) each
        cw = jnp.cumsum(wc, axis=1)            # inclusive cumulative log-decay
        cw_prev = cw - wc                       # exclusive (cw_{t-1})
        # cross-chunk: o_t += (r_t ⊙ e^{cw_{t-1}}) S0
        rd = rc * jnp.exp(cw_prev)
        cross = jnp.einsum("blhd,bhde->blhe", rd, S0)
        # intra-chunk (i < t), exponents cw_prev[t] - cw[i] <= 0 for i <= t-1
        diff = cw_prev[:, :, None] - cw[:, None]          # (B,L,L,Hl,hd)
        tri = jnp.tril(jnp.ones((L, L), bool), k=-1)
        D = jnp.where(tri[None, :, :, None, None], jnp.exp(diff), 0.0)
        P = jnp.einsum("bthd,bihd,btihd->btih", rc, kc, D)
        intra = jnp.einsum("btih,bihe->bthe", P, vc)
        # bonus (current token): (r_t . (u ⊙ k_t)) v_t
        bonus = jnp.einsum("bthd,hd,bthd->bth", rc, u, kc)[..., None] * vc
        # state to end of chunk
        kd = kc * jnp.exp(cw[:, -1:] - cw)
        S1 = jnp.exp(cw[:, -1])[..., None] * S0 + jnp.einsum(
            "bihd,bihe->bhde", kd, vc)
        return S1, cross + intra + bonus

    def split(t):
        return t.reshape(B, nc, L, Hl, hd).transpose(1, 0, 2, 3, 4)

    state, out = jax.lax.scan(
        chunk_step, state, (split(r32), split(k32), split(v32), split(logw))
    )
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, S, Hl, hd)

    out = _group_rms(out, p["ln_x"], cfg.norm_eps)
    out = out * jax.nn.silu(g.astype(jnp.float32)).astype(out.dtype)
    y = ctx.psum_tp(out @ p["wo"])
    if return_state:
        return y, (state, x[:, -1:])
    return y, None


def rwkv_decode(ctx: TPCtx, cfg: ModelConfig, dims: Dims, p, x, cache):
    """Single-token step; x: (B,1,d); cache = (state (B,Hl,hd,hd),
    prev_x (B,1,d)).  Returns (y (B,1,d), new cache)."""
    B = x.shape[0]
    _, Hl, hd = rwkv_dims(cfg, ctx.tp)
    state, prev_x = cache
    xf, xs = x[:, 0], prev_x[:, 0]
    r = (_mix(xf, xs, p["mu_r"]) @ p["proj_r"]).reshape(B, Hl, hd)
    k = (_mix(xf, xs, p["mu_k"]) @ p["proj_k"]).reshape(B, Hl, hd)
    v = (_mix(xf, xs, p["mu_v"]) @ p["proj_v"]).reshape(B, Hl, hd)
    g = _mix(xf, xs, p["mu_g"]) @ p["proj_g"]
    logw = _decay_log(ctx, p, _mix(xf, xs, p["mu_w"]), Hl, hd).reshape(
        B, Hl, hd)
    u = p["u"].reshape(Hl, hd).astype(jnp.float32)

    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    o = jnp.einsum("bhd,bhde->bhe", r32, state)
    o = o + jnp.einsum("bhd,hd,bhd->bh", r32, u, k32)[..., None] * v32
    state = jnp.exp(logw)[..., None] * state + jnp.einsum(
        "bhd,bhe->bhde", k32, v32)

    o = _group_rms(o[:, None], p["ln_x"], cfg.norm_eps)        # (B,1,dl)
    o = o * jax.nn.silu(g.astype(jnp.float32))[:, None].astype(o.dtype)
    y = ctx.psum_tp(o @ p["wo"])
    return y, (state, x)

"""Composable decoder stack covering all assigned architecture families.

Parameter layout (the repo-wide convention):
  * every TP-sharded leaf is stored with a leading mesh axis:
      slot (per-layer) leaves: (n_groups, tp, *local_shape)  P(None,'model')
      global leaves (embed, lm_head): (tp, *local_shape)     P('model')
      tiny replicated leaves (final_norm): local shape       P()
  * inside ``shard_map`` the tp axis arrives with extent 1 and is squeezed.

The layer stack is executed as ``lax.scan`` over ``n_groups`` repetitions
of a ``group_size``-slot pattern (config.py), keeping HLO size O(group).
All collectives are explicit: psum('model') row-parallel combines,
vocab-parallel embedding / CE loss, sequence-sharded decode caches.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.dist import fsdp as fsdp_lib
from . import attention, mamba, moe, rwkv
from .config import ATTN, CROSS, FULL, MAMBA, RWKV, ModelConfig
from .layers import (
    Dims,
    TPCtx,
    dense_init,
    embed_lookup,
    ffn_forward,
    ffn_param_specs,
    lm_head_logits,
    lm_head_loss,
    make_dims,
    rms_norm,
)

P = jax.sharding.PartitionSpec


# ---------------------------------------------------------------------------
# parameter specs / init
# ---------------------------------------------------------------------------

def _init_leaf(key, shape, code, dtype, cfg: ModelConfig):
    if code == -1:
        return jnp.ones(shape, dtype)
    if code == -2:  # mamba A_log: log(1..d_state) per channel
        st = shape[-1]
        a = jnp.broadcast_to(jnp.log(jnp.arange(1, st + 1, dtype=jnp.float32)),
                             shape)
        return a.astype(dtype)
    if code == -4:  # token-shift mixing factors
        return jnp.full(shape, 0.5, dtype)
    if code == 0:
        return jnp.zeros(shape, dtype)
    return dense_init(key, shape, code, dtype)


# Leaves that are REPLICATED across the model axis must be initialized
# rank-invariantly: the kv projections (the decode cache is read across
# sequence shards on other ranks) and the MoE router (all ranks must
# route identically for the expert-psum combine to be coherent).
REPLICATED_LEAVES = {"wk", "wv", "bk", "bv", "router", "w_lora_a",
                     "w_lora_b", "w0", "mu_r", "mu_k", "mu_v", "mu_g",
                     "mu_w"}


def _init_tree(key, specs, dtype, cfg, rank=None):
    out = {}
    names = sorted(specs.keys())
    for i, name in enumerate(names):
        sub = specs[name]
        k = jax.random.fold_in(key, i)
        if isinstance(sub, dict):
            out[name] = _init_tree(k, sub, dtype, cfg, rank)
        else:
            if rank is not None and name not in REPLICATED_LEAVES:
                k = jax.random.fold_in(k, rank + 1)
            shape, code = sub
            out[name] = _init_leaf(k, shape, code, dtype, cfg)
    return out


def slot_param_specs(cfg: ModelConfig, dims: Dims, tp: int, slot: int):
    d = cfg.d_model
    kind = cfg.slot_kind(slot)
    specs: dict[str, Any] = {
        "norm1": ((d,), -1),
        "norm2": ((d,), -1),
    }
    if kind == ATTN:
        specs["mixer"] = attention.attn_param_specs(cfg, dims)
    elif kind == RWKV:
        specs["mixer"] = rwkv.rwkv_param_specs(cfg, dims, tp)
    elif kind == MAMBA:
        specs["mixer"] = mamba.mamba_param_specs(cfg, dims, tp)
    else:
        raise ValueError(kind)
    if cfg.slot_has_cross(slot):
        specs["cross_norm"] = ((d,), -1)
        specs["cross"] = attention.attn_param_specs(cfg, dims, cross=True)
    if cfg.slot_is_moe(slot):
        specs["ffn"] = moe.moe_param_specs(cfg, dims, tp)
    else:
        specs["ffn"] = ffn_param_specs(cfg, dims)
    return specs


class Model:
    """One architecture on one mesh. All apply-methods assume they run
    inside shard_map with manual axes (ctx.model_axis + ctx.data_axes)."""

    def __init__(self, cfg: ModelConfig, *, tp: int, dp: int = 1,
                 model_axis: str = "model", data_axes: tuple = ("data",),
                 seq_shard_axes: tuple | None = None,
                 remat: str = "full", param_mode: str = "dp",
                 fsdp_scheme=None, fsdp_sync: str = "quantized",
                 fsdp_use_pallas: bool = False, fsdp_codec=None):
        """remat: 'full' (recompute each layer group in bwd — O(1-layer)
        activation memory), 'dots' (save matmul outputs), or 'none'.

        param_mode: 'dp' (params replicated over the data axes — the
        paper's Algorithm-1 setting) or 'fsdp' (params stored flat and
        sharded over the data axes, gathered per layer group; gradients
        aggregate inside the gather's custom_vjp — quantized when
        fsdp_sync='quantized' with `fsdp_scheme`, else fp32
        psum_scatter; `fsdp_codec` overrides the wire codec, e.g. a
        MixedWidthCodec).  Big-arch configs need fsdp to fit HBM."""
        self.cfg = cfg
        self.tp = tp
        self.dp = dp
        self.dims = make_dims(cfg, tp)
        self.ctx = TPCtx(
            model_axis=model_axis,
            data_axes=data_axes,
            tp=tp,
            dp=dp,
            compute_dtype=jnp.dtype(cfg.compute_dtype),
        )
        # axes over which decode caches are sequence-sharded
        self.seq_shard_axes = seq_shard_axes or (model_axis,)
        self.remat = remat
        self._max_len = 0

        # ---- FSDP layout metadata ----
        self.param_mode = param_mode
        if param_mode == "fsdp":
            from repro.core.codec import codec_for_scheme
            from repro.core.schemes import QuantScheme
            scheme = fsdp_scheme or QuantScheme(name="fp32")
            self._fsdp_scheme = scheme
            # the codec that actually rides the backward wire — exposed
            # so train_step's metrics report THIS, not its own config
            self._fsdp_codec = (fsdp_codec if fsdp_codec is not None
                                else codec_for_scheme(scheme))
            self._gather = fsdp_lib.make_gather(
                data_axes, scheme, fsdp_sync,
                use_pallas=fsdp_use_pallas, codec=self._fsdp_codec)
            self._slot_meta = []
            self._slot_len = []
            world = dp
            for s in range(cfg.group_size):
                meta = fsdp_lib.flatten_meta(
                    slot_param_specs(cfg, self.dims, tp, s))
                self._slot_meta.append(meta)
                self._slot_len.append(fsdp_lib.padded_flat_len(
                    meta, scheme.bucket_size, world, dp))
            d = cfg.d_model
            self._embed_meta = [(("embed",), (self.dims.vocab_local, d), d)]
            self._lm_meta = [(("lm_head",), (d, self.dims.vocab_local), d)]
            self._embed_len = fsdp_lib.padded_flat_len(
                self._embed_meta, scheme.bucket_size, world, dp)
            self._lm_len = fsdp_lib.padded_flat_len(
                self._lm_meta, scheme.bucket_size, world, dp)
            self._dummy_ctx = (scheme.init_state().levels,
                               jax.random.PRNGKey(0))

    # ---- params ---------------------------------------------------------

    def init(self, key) -> dict:
        """Global params (leading mesh axes materialized by vmapping the
        per-(group, rank) local init)."""
        if self.param_mode == "fsdp":
            return self._init_fsdp(key)
        cfg, dims, tp = self.cfg, self.dims, self.tp
        pdt = jnp.dtype(cfg.param_dtype)
        d = cfg.d_model

        def global_leaf(k, shape, code):
            def per_rank(r):
                return _init_leaf(jax.random.fold_in(k, r), shape, code, pdt,
                                  cfg)
            return jax.vmap(per_rank)(jnp.arange(tp))

        params = {
            "embed": global_leaf(jax.random.fold_in(key, 0),
                                 (dims.vocab_local, d), d),
            "lm_head": global_leaf(jax.random.fold_in(key, 1),
                                   (d, dims.vocab_local), d),
            "final_norm": jnp.ones((d,), pdt),
        }

        slots = []
        for slot in range(cfg.group_size):
            specs = slot_param_specs(cfg, dims, tp, slot)

            def init_one(g, r, slot=slot, specs=specs):
                k = jax.random.fold_in(
                    jax.random.fold_in(key, 100 + slot), g)
                return _init_tree(k, specs, pdt, cfg, rank=r)

            stacked = jax.vmap(
                lambda g: jax.vmap(lambda r: init_one(g, r))(jnp.arange(tp))
            )(jnp.arange(cfg.num_groups))
            slots.append(stacked)
        params["slots"] = slots
        return params

    def _init_fsdp(self, key) -> dict:
        """Flat FSDP layout: each slot (n_groups, tp, Lp); Lp sharded over
        the data axes at rest."""
        cfg, tp = self.cfg, self.tp
        pdt = jnp.dtype(cfg.param_dtype)

        def flat_of(tree, meta, Lp):
            leaves = []
            node_lookup = tree
            for path, shape, _ in meta:
                node = node_lookup
                for p in path:
                    node = node[p]
                leaves.append(node.reshape(-1))
            flat = jnp.concatenate(leaves)
            return jnp.pad(flat, (0, Lp - flat.shape[0]))

        params = {"final_norm": jnp.ones((cfg.d_model,), pdt)}

        def embed_leaf(k, meta, Lp):
            def per_rank(r):
                path, shape, code = meta[0]
                leaf = _init_leaf(jax.random.fold_in(k, r), shape, code,
                                  pdt, cfg)
                return jnp.pad(leaf.reshape(-1), (0, Lp - leaf.size))
            return jax.vmap(per_rank)(jnp.arange(tp))

        params["embed"] = embed_leaf(jax.random.fold_in(key, 0),
                                     self._embed_meta, self._embed_len)
        params["lm_head"] = embed_leaf(jax.random.fold_in(key, 1),
                                       self._lm_meta, self._lm_len)

        slots = []
        for slot in range(cfg.group_size):
            specs = slot_param_specs(cfg, self.dims, tp, slot)
            meta = self._slot_meta[slot]
            Lp = self._slot_len[slot]

            def init_one(g, r, specs=specs, meta=meta, Lp=Lp, slot=slot):
                k = jax.random.fold_in(
                    jax.random.fold_in(key, 100 + slot), g)
                return flat_of(_init_tree(k, specs, pdt, cfg, rank=r),
                               meta, Lp)

            stacked = jax.vmap(
                lambda g: jax.vmap(lambda r: init_one(g, r))(jnp.arange(tp))
            )(jnp.arange(cfg.num_groups))
            slots.append(stacked)
        params["slots"] = slots
        return params

    def param_struct(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def param_specs(self):
        """PartitionSpec pytree matching init()'s output."""
        struct = self.param_struct()
        if self.param_mode == "fsdp":
            da = tuple(self.ctx.data_axes)
            return {
                "embed": P("model", da),
                "lm_head": P("model", da),
                "final_norm": P(),
                "slots": [P(None, "model", da) for _ in struct["slots"]],
            }
        return {
            "embed": P("model"),
            "lm_head": P("model"),
            "final_norm": P(),
            "slots": jax.tree.map(lambda _: P(None, "model"),
                                  struct["slots"]),
        }

    # ---- one layer slot ---------------------------------------------------

    def _apply_slot(self, slot, p, x, positions, vision, mode, cache,
                    pos, cache_shards):
        cfg, dims, ctx = self.cfg, self.dims, self.ctx
        kind = cfg.slot_kind(slot)
        akind = cfg.slot_attn_kind(slot)
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        new_cache = cache
        if kind == ATTN:
            if mode == "decode":
                mix, new_cache = attention.attn_decode(
                    ctx, cfg, dims, p["mixer"], h, pos, cache, akind,
                    cache_shards=cache_shards,
                    seq_shard_axes=self.seq_shard_axes)
            else:
                mix, new_cache = attention.attn_forward(
                    ctx, cfg, dims, p["mixer"], h, positions, akind,
                    return_cache=(mode == "prefill"),
                    max_len=self._max_len, cache_shards=cache_shards,
                    seq_shard_axes=self.seq_shard_axes)
        elif kind == RWKV:
            if mode == "decode":
                mix, new_cache = rwkv.rwkv_decode(
                    ctx, cfg, dims, p["mixer"], h, cache)
            else:
                mix, new_cache = rwkv.rwkv_forward(
                    ctx, cfg, dims, p["mixer"], h,
                    return_state=(mode == "prefill"))
        elif kind == MAMBA:
            if mode == "decode":
                mix, new_cache = mamba.mamba_decode(
                    ctx, cfg, dims, p["mixer"], h, cache)
            else:
                mix, new_cache = mamba.mamba_forward(
                    ctx, cfg, dims, p["mixer"], h,
                    return_state=(mode == "prefill"))
        else:
            raise ValueError(kind)
        x = x + mix.astype(x.dtype)

        if cfg.slot_has_cross(slot) and vision is not None:
            hc = rms_norm(x, p["cross_norm"], cfg.norm_eps)
            x = x + attention.cross_attn_forward(
                ctx, cfg, dims, p["cross"], hc, vision).astype(x.dtype)

        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.slot_is_moe(slot):
            y, aux = moe.moe_ffn(ctx, cfg, p["ffn"], h2)
        else:
            y, aux = ffn_forward(ctx, p["ffn"], h2), 0.0
        return x + y.astype(x.dtype), new_cache, aux

    # ---- stacks -----------------------------------------------------------

    @staticmethod
    def _squeeze_tp(tree):
        return jax.tree.map(lambda a: a.squeeze(0), tree)

    def _cast_compute(self, tree):
        """Master params (f32) -> compute dtype for the matmul path; AD
        routes cotangents back to f32 through the cast."""
        cd = self.ctx.compute_dtype

        def cast(a):
            return a.astype(cd) if jnp.issubdtype(a.dtype, jnp.floating) else a

        return jax.tree.map(cast, tree)

    def _materialize_slot(self, s, sliced, sync_ctx):
        """Per-group param slice -> layer param dict (FSDP: gather)."""
        if self.param_mode != "fsdp":
            return self._cast_compute(self._squeeze_tp(sliced))
        shard = sliced.squeeze(0)        # (Lp / dp,)
        levels, key = sync_ctx if sync_ctx is not None else self._dummy_ctx
        full = self._gather(shard, levels, jax.random.fold_in(key, s))
        return fsdp_lib.unflatten(full, self._slot_meta[s],
                                  self.ctx.compute_dtype)

    def _embed_weights(self, params, sync_ctx):
        if self.param_mode != "fsdp":
            return self._cast_compute(params["embed"].squeeze(0))
        levels, key = sync_ctx if sync_ctx is not None else self._dummy_ctx
        full = self._gather(params["embed"].squeeze(0), levels,
                            jax.random.fold_in(key, 1001))
        (_, shape, _), = self._embed_meta
        return full[: shape[0] * shape[1]].reshape(shape).astype(
            self.ctx.compute_dtype)

    def _lm_weights(self, params, sync_ctx):
        if self.param_mode != "fsdp":
            return self._cast_compute(params["lm_head"].squeeze(0))
        levels, key = sync_ctx if sync_ctx is not None else self._dummy_ctx
        full = self._gather(params["lm_head"].squeeze(0), levels,
                            jax.random.fold_in(key, 1002))
        (_, shape, _), = self._lm_meta
        return full[: shape[0] * shape[1]].reshape(shape).astype(
            self.ctx.compute_dtype)

    def _run_stack(self, params, x, positions, vision, mode, caches, pos,
                   cache_shards, sync_ctx=None):
        """lax.scan over groups. caches: list per slot of stacked pytrees
        (or None).  Returns (x, new_caches, aux)."""
        cfg = self.cfg
        G = cfg.group_size

        nested_ckpt = (mode == "train" and self.remat == "full" and G > 1)

        def body(carry, xs):
            x, aux = carry
            slot_ps, slot_caches = xs
            new_slot_caches = []
            for s in range(G):
                def one_slot(sliced, x, s=s):
                    p = self._materialize_slot(s, sliced, sync_ctx)
                    c = (slot_caches[s] if slot_caches is not None
                         else None)
                    return self._apply_slot(
                        s, p, x, positions, vision, mode, c, pos,
                        cache_shards)

                if nested_ckpt:
                    # bound the group's bwd transients to one slot at a
                    # time (jamba groups hold 8 heterogeneous slots)
                    one_slot = jax.checkpoint(one_slot)
                x, nc, a = one_slot(slot_ps[s], x)
                new_slot_caches.append(nc)
                aux = aux + a
            ys = tuple(new_slot_caches) if mode != "train" else None
            return (x, aux), ys

        if mode == "train" and self.remat != "none":
            if self.remat == "dots":
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.checkpoint_dots)
            elif self.remat == "psum":
                # full remat EXCEPT collective outputs: replaying compute
                # is cheap, replaying psums costs ICI twice
                body = jax.checkpoint(
                    body,
                    policy=jax.checkpoint_policies.save_only_these_names(
                        "tp_psum"))
            else:
                body = jax.checkpoint(body)

        slot_ps = tuple(params["slots"])
        xs = (slot_ps, tuple(caches) if caches is not None else None)
        (x, aux), new_caches = jax.lax.scan(body, (x, 0.0), xs)
        return x, new_caches, aux

    # ---- public entry points ----------------------------------------------

    def forward(self, params, ids, vision=None, sync_ctx=None):
        """Train-mode forward to final hidden states (B, S, d)."""
        ctx, cfg = self.ctx, self.cfg
        B, S = ids.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = embed_lookup(ctx, self._embed_weights(params, sync_ctx), ids)
        x, _, aux = self._run_stack(params, x, positions, vision, "train",
                                    None, None, 1, sync_ctx)
        x = rms_norm(x, self._cast_compute(params["final_norm"]),
                     cfg.norm_eps)
        return x, aux

    def loss(self, params, batch, sync_ctx=None):
        """Mean CE loss (+ MoE aux). batch: ids, labels[, vision].

        sync_ctx=(levels, key) routes the FSDP backward's quantized
        reduce-scatter (ignored in DP mode)."""
        x, aux = self.forward(params, batch["ids"], batch.get("vision"),
                              sync_ctx)
        ce = lm_head_loss(self.ctx, self._lm_weights(params, sync_ctx), x,
                          batch["labels"], self.cfg.vocab_size)
        return ce + aux / max(self.cfg.num_layers, 1)

    def prefill(self, params, ids, vision=None, *, max_len: int = 0,
                cache_shards: int = 1):
        """Returns (last-token logits, caches list-per-slot) with caches
        laid out exactly as decode's ring addressing expects."""
        ctx, cfg = self.ctx, self.cfg
        B, S = ids.shape
        self._max_len = max_len or S
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = embed_lookup(ctx, self._embed_weights(params, None), ids)
        caches = [None] * cfg.group_size
        x, new_caches, _ = self._run_stack(params, x, positions, vision,
                                           "prefill", caches, None,
                                           cache_shards)
        x = rms_norm(x, self._cast_compute(params["final_norm"]),
                     cfg.norm_eps)
        logits = lm_head_logits(ctx, self._lm_weights(params, None),
                                x[:, -1], cfg.vocab_size)
        return logits, list(new_caches)

    def decode(self, params, token, pos, caches, vision=None,
               cache_shards: int | None = None):
        """One decode step. token: (B,) ids; pos: (B,) absolute positions;
        caches: list per slot of stacked (n_groups, ...) pytrees."""
        ctx, cfg = self.ctx, self.cfg
        if cache_shards is None:
            cache_shards = 1
            for ax in self.seq_shard_axes:
                cache_shards *= {"model": self.tp}.get(ax, self.dp)
        B = token.shape[0]
        x = embed_lookup(ctx, self._embed_weights(params, None),
                         token[:, None])
        x, new_caches, _ = self._run_stack(
            params, x, pos[:, None], vision, "decode", caches, pos,
            cache_shards)
        x = rms_norm(x, self._cast_compute(params["final_norm"]),
                     cfg.norm_eps)
        logits = lm_head_logits(ctx, self._lm_weights(params, None),
                                x[:, 0], cfg.vocab_size)
        return logits, list(new_caches)

    # ---- cache construction -------------------------------------------------

    def global_cache_struct(self, batch_global: int, max_len: int,
                            cache_shards: int, dtype=jnp.bfloat16):
        """ShapeDtypeStructs for the GLOBAL (unsharded) decode caches."""
        cfg = self.cfg
        dims = self.dims
        out = []
        for slot in range(cfg.group_size):
            kind = cfg.slot_kind(slot)
            if kind == ATTN:
                akind = cfg.slot_attn_kind(slot)
                C, _ = attention.cache_spec(cfg, dims, akind, max_len,
                                            cache_shards)
                sh = (cfg.num_groups, batch_global, C, dims.n_kv_heads,
                      dims.head_dim)
                c = (jax.ShapeDtypeStruct(sh, dtype),
                     jax.ShapeDtypeStruct(sh, dtype))
            elif kind == RWKV:
                nH, _, hd = rwkv.rwkv_dims(cfg, self.tp)
                c = (jax.ShapeDtypeStruct(
                        (cfg.num_groups, batch_global, nH, hd, hd),
                        jnp.float32),
                     jax.ShapeDtypeStruct(
                        (cfg.num_groups, batch_global, 1, cfg.d_model),
                        dtype))
            else:  # MAMBA
                di, _ = mamba.mamba_dims(cfg, self.tp)
                c = (jax.ShapeDtypeStruct(
                        (cfg.num_groups, batch_global, di,
                         cfg.mamba_d_state), jnp.float32),
                     jax.ShapeDtypeStruct(
                        (cfg.num_groups, batch_global, cfg.mamba_conv - 1,
                         di), dtype))
            out.append(c)
        return out

    def cache_pspecs(self, batch_axes: tuple):
        """PartitionSpecs matching global_cache_struct / init_cache.

        Attention caches are sequence-sharded over self.seq_shard_axes;
        recurrent states shard their channel/head dim over the model axis.
        """
        cfg = self.cfg
        b = tuple(batch_axes) if batch_axes else None
        seq = tuple(self.seq_shard_axes)
        out = []
        for slot in range(cfg.group_size):
            kind = cfg.slot_kind(slot)
            if kind == ATTN:
                s = P(None, b, seq)
                out.append((s, s))
            elif kind == RWKV:
                out.append((P(None, b, "model"), P(None, b)))
            else:
                out.append((P(None, b, "model"), P(None, b, None, "model")))
        return out

    def init_cache(self, batch: int, max_len: int, cache_shards: int,
                   dtype=jnp.bfloat16):
        """Zero caches (list per slot of (n_groups, ...)-stacked pytrees),
        *local* shapes for one device; use cache_struct for global."""
        cfg, dims = self.cfg, self.dims
        out = []
        for slot in range(cfg.group_size):
            kind = cfg.slot_kind(slot)
            if kind == ATTN:
                akind = cfg.slot_attn_kind(slot)
                _, cl = attention.cache_spec(cfg, dims, akind, max_len,
                                             cache_shards)
                kv = dims.n_kv_heads
                c = (
                    jnp.zeros((cfg.num_groups, batch, cl, kv, dims.head_dim),
                              dtype),
                    jnp.zeros((cfg.num_groups, batch, cl, kv, dims.head_dim),
                              dtype),
                )
            elif kind == RWKV:
                _, hl, hd = rwkv.rwkv_dims(cfg, self.tp)
                c = (
                    jnp.zeros((cfg.num_groups, batch, hl, hd, hd),
                              jnp.float32),
                    jnp.zeros((cfg.num_groups, batch, 1, cfg.d_model), dtype),
                )
            else:  # MAMBA
                _, dil = mamba.mamba_dims(cfg, self.tp)
                c = (
                    jnp.zeros(
                        (cfg.num_groups, batch, dil, cfg.mamba_d_state),
                        jnp.float32),
                    jnp.zeros(
                        (cfg.num_groups, batch, cfg.mamba_conv - 1, dil),
                        dtype),
                )
            out.append(c)
        return out

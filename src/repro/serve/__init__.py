"""Serving: batched prefill + decode against sharded KV caches."""
from .engine import ServeConfig, make_prefill_step, make_decode_step

"""Serving engine: batched prefill and decode steps for shard_map.

``serve_step`` for the decode input shapes is ONE new token against a KV
cache of ``seq_len`` — greedy sampling on the gathered last-position
logits.  The cache is sequence-sharded (attention.py); for batch-1
long-context the sharding axes extend over the data axes too.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.transformer import Model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 4096
    cache_dtype: str = "bfloat16"
    greedy: bool = True
    temperature: float = 1.0


def make_prefill_step(model: Model, scfg: ServeConfig, *, cache_shards: int):
    def prefill_step(params, ids, vision=None):
        logits, caches = model.prefill(
            params, ids, vision, max_len=scfg.max_len,
            cache_shards=cache_shards)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, caches

    return prefill_step


def make_decode_step(model: Model, scfg: ServeConfig, *, cache_shards: int):
    def decode_step(params, token, pos, caches, vision=None):
        logits, caches = model.decode(
            params, token, pos, caches, vision, cache_shards=cache_shards)
        if scfg.greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jnp.argmax(logits / scfg.temperature, axis=-1).astype(
                jnp.int32)
        return nxt, caches

    return decode_step

"""repro.sim — a cluster simulator for quantized data-parallel training.

Runs M logical workers on one host against pluggable aggregation
topologies (flat allreduce via the real ``repro.dist`` collectives under
vmap, a QSGD-style parameter server, a per-hop-re-quantizing ring) and
heterogeneous cluster models (bandwidth spread, stragglers, dropout),
emitting per-step JSON trajectories of loss, wire bytes, simulated
wall-clock, and gradient-statistics drift.

    python -m repro.sim --scenario paper_mlp

See docs/simulator.md for topologies, the cost model, and the JSON
schema.
"""
from .cluster import (  # noqa: F401
    ClusterConfig,
    ClusterState,
    init_cluster_state,
    sample_step,
    step_faults,
    step_time_ms,
)
from .scenario import SCENARIOS, Scenario, register, run_scenario  # noqa: F401
from .topology import (  # noqa: F401
    SIM_AXIS,
    TOPOLOGIES,
    TopologyResult,
    run_compressed,
    run_topology,
)

"""CLI: run a named scenario grid and write JSON trajectories.

    python -m repro.sim --scenario paper_mlp
    python -m repro.sim --scenario stragglers --steps 20 --workers 8
    python -m repro.sim --list
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sim",
        description="Cluster simulator for quantized data-parallel SGD: "
                    "runs a (scheme x topology) scenario grid with M "
                    "logical workers on one host and writes per-step "
                    "JSON trajectories.")
    ap.add_argument("--scenario", default="paper_mlp",
                    help="registered scenario name (see --list)")
    ap.add_argument("--steps", type=int, default=None,
                    help="override the scenario's step count")
    ap.add_argument("--workers", type=int, default=None,
                    help="override the cluster's worker count")
    ap.add_argument("--out", default=None,
                    help="output path (default: SIM_<scenario>.json)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="run the Pallas kernel path (interpret mode on "
                         "CPU; slower, kernel-faithful)")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    args = ap.parse_args(argv)

    from repro.sim import SCENARIOS, run_scenario

    if args.list:
        for name, s in sorted(SCENARIOS.items()):
            grid = f"{len(s.schemes)}x{len(s.topologies)}"
            if len(s.compress) > 1:
                grid += f"x{len(s.compress)}"
            print(f"{name:20s} [{grid} grid, {s.cluster.num_workers} "
                  f"workers, {s.steps} steps] {s.description}")
        return 0

    if args.scenario not in SCENARIOS:
        print(f"unknown scenario {args.scenario!r}; known: "
              f"{sorted(SCENARIOS)}", file=sys.stderr)
        return 2

    t0 = time.perf_counter()
    result = run_scenario(SCENARIOS[args.scenario], steps=args.steps,
                          workers=args.workers,
                          use_pallas=args.use_pallas)
    result["wallclock_s"] = round(time.perf_counter() - t0, 3)

    out_path = args.out or f"SIM_{args.scenario}.json"
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    ncells = len(result["cells"])
    print(f"wrote {out_path}: {ncells} cells x "
          f"{result['num_steps']} steps in {result['wallclock_s']}s")
    for c in result["cells"]:
        t = c["totals"]
        print(f"  {c['scheme']:10s} {c['topology']:12s} "
              f"final_loss={t['final_loss']:.4f} "
              f"sim_time={t['sim_time_ms']:.1f}ms "
              f"wire={t['wire_bytes']:.3e}B "
              f"agg_err={t['mean_agg_err']:.3e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Worker heterogeneity models and the simulated wall-clock cost model.

The simulator is bulk-synchronous: a step's simulated time is the
makespan of its slowest surviving worker plus whatever the aggregation
point serializes.  All randomness (straggler draws, dropout draws,
compute jitter) is host-side numpy, seeded from ``(seed, step)`` with a
``SeedSequence`` — the same scenario config always produces the same
trajectory, bit for bit.

Cost model (formulas also in docs/simulator.md):

    compute_w = compute_ms * jitter_w * (straggler_scale if straggling)
    comm_w    = sent_bytes_w / bw_w + recv_bytes_w / bw_w
    t_step    = max over ACTIVE workers (compute_w + comm_w)
                + server_bytes / server_bw          (param_server only)
                + hops * latency_ms

with per-worker full-duplex link bandwidth ``bw_w`` (heterogeneous when
``bandwidth_gbps`` is a tuple) and one shared server link.  Dropped
workers spend no time (they are absent for the step) and their payloads
are excluded from the aggregate by the topology layer.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """One logical cluster: link speeds, stragglers, dropout."""

    num_workers: int = 4
    # per-worker link bandwidth; scalar = homogeneous, tuple = one entry
    # per worker (cycled if shorter than num_workers)
    bandwidth_gbps: float | tuple = 10.0
    server_bandwidth_gbps: float = 40.0   # param-server ingress+egress link
    compute_ms: float = 10.0              # base per-step gradient compute
    compute_jitter: float = 0.0           # lognormal sigma on compute time
    straggler_prob: float = 0.0           # P[worker straggles this step]
    straggler_scale: float = 1.0          # compute multiplier when straggling
    dropout_prob: float = 0.0             # P[worker absent this step]
    latency_ms: float = 0.05              # per serialized hop
    seed: int = 0

    def __post_init__(self):
        if self.num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        if self.straggler_scale < 1.0:
            raise ValueError("straggler_scale must be >= 1 (it multiplies "
                             "compute time)")
        for f in ("straggler_prob", "dropout_prob"):
            p = getattr(self, f)
            if not 0.0 <= float(p) <= 1.0:
                raise ValueError(f"{f} must be in [0, 1], got {p}")
        if not np.isscalar(self.bandwidth_gbps):
            if len(self.bandwidth_gbps) == 0:
                raise ValueError(
                    "bandwidth_gbps tuple must be non-empty (it is "
                    "cycled over workers)")
            bad = [b for b in self.bandwidth_gbps if float(b) <= 0]
            if bad:
                raise ValueError(f"bandwidth_gbps must be > 0, got {bad}")
        elif float(self.bandwidth_gbps) <= 0:
            raise ValueError("bandwidth_gbps must be > 0, got "
                             f"{self.bandwidth_gbps}")


def worker_bandwidths(cfg: ClusterConfig) -> np.ndarray:
    """(M,) per-worker link bandwidth in bytes/ms."""
    bw = cfg.bandwidth_gbps
    if np.isscalar(bw):
        per = np.full(cfg.num_workers, float(bw))
    else:
        per = np.array([float(bw[i % len(bw)])
                        for i in range(cfg.num_workers)])
    # 1 Gb/s = 1e9 bits/s = 1.25e5 bytes/ms
    return per * 1.25e5


def _rng(cfg: ClusterConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xC1A5]))


def sample_step(cfg: ClusterConfig, step: int):
    """Deterministic per-step draw -> (compute_ms (M,), active (M,) f32).

    Uses one uniform per worker per effect so the draws are COUPLED
    across config changes: raising ``straggler_prob`` or
    ``straggler_scale`` at a fixed seed can only slow workers down,
    which is what makes the monotonicity property testable.

    Worker 0 never drops: the cluster always has at least one survivor.
    """
    M = cfg.num_workers
    rng = _rng(cfg, step)
    u_straggle = rng.random(M)
    u_drop = rng.random(M)
    jitter = (np.exp(cfg.compute_jitter * rng.standard_normal(M))
              if cfg.compute_jitter > 0 else np.ones(M))

    straggling = u_straggle < cfg.straggler_prob
    factor = np.where(straggling, cfg.straggler_scale, 1.0)
    compute = cfg.compute_ms * jitter * factor

    active = (u_drop >= cfg.dropout_prob).astype(np.float32)
    active[0] = 1.0
    return compute, active


# ---------------------------------------------------------------------------
# crash / rejoin: the per-worker up/down Markov chain
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClusterState:
    """Mutable cross-step cluster state: which workers are up, and for
    how many consecutive steps the down ones have been down (the
    staleness of the payload they will rejoin with)."""

    up: np.ndarray           # (M,) bool
    down_steps: np.ndarray   # (M,) int


def init_cluster_state(num_workers: int) -> ClusterState:
    return ClusterState(up=np.ones(num_workers, bool),
                        down_steps=np.zeros(num_workers, np.int64))


def step_faults(faults, state: ClusterState, step: int):
    """Advance the crash/rejoin Markov chain one step.

    ``faults`` is a ``dist.faults.FaultModel`` (``crash_prob`` /
    ``rejoin_prob`` / ``seed``); draws are host-side numpy seeded from
    ``(faults.seed, step)`` — deterministic, same discipline as
    ``sample_step``.  Worker 0 never crashes (the cluster always has a
    survivor, matching the dropout model).

    Returns ``(new_state, weight, events)``:

    * ``weight`` is the (M,) float contribution weight for THIS step:
      1.0 for a healthy worker, 0.0 while down, and the staleness
      weight ``1 / (1 + k)`` on the step a worker rejoins after ``k``
      steps down — its payload is a stale gradient, down-weighted in
      the ``MaskedTransport`` renormalization (the first slice of the
      async/decentralized aggregation story).
    * ``events`` is a JSON-ready list of this step's transitions.
    """
    M = state.up.shape[0]
    rng = np.random.default_rng(
        np.random.SeedSequence([faults.seed, step, 0xFA17]))
    u_crash = rng.random(M)
    u_rejoin = rng.random(M)

    up = state.up.copy()
    down = state.down_steps.copy()
    weight = np.ones(M, np.float32)
    events = []
    for w in range(M):
        if up[w]:
            if w != 0 and u_crash[w] < faults.crash_prob:
                up[w] = False
                down[w] = 1
                weight[w] = 0.0
                events.append({"step": step, "worker": w,
                               "event": "crash"})
        else:
            if u_rejoin[w] < faults.rejoin_prob:
                k = int(down[w])
                up[w] = True
                down[w] = 0
                weight[w] = np.float32(1.0 / (1.0 + k))
                events.append({"step": step, "worker": w,
                               "event": "rejoin", "staleness": k,
                               "weight": float(weight[w])})
            else:
                down[w] += 1
                weight[w] = 0.0
    return ClusterState(up=up, down_steps=down), weight, events


def step_time_ms(
    cfg: ClusterConfig,
    compute_ms: np.ndarray,
    active: np.ndarray,
    sent_bytes: np.ndarray,
    recv_bytes: np.ndarray,
    server_bytes: float,
    hops: int,
) -> float:
    """Simulated wall-clock of one bulk-synchronous step (formula above)."""
    bw = worker_bandwidths(cfg)
    comm = (np.asarray(sent_bytes) + np.asarray(recv_bytes)) / bw
    per_worker = np.asarray(compute_ms) + comm
    mask = np.asarray(active) > 0
    makespan = float(per_worker[mask].max()) if mask.any() else 0.0
    server = float(server_bytes) / (cfg.server_bandwidth_gbps * 1.25e5)
    return makespan + server + float(hops) * cfg.latency_ms

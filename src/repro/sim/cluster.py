"""Worker heterogeneity models and the simulated wall-clock cost model.

The simulator is bulk-synchronous: a step's simulated time is the
makespan of its slowest surviving worker plus whatever the aggregation
point serializes.  All randomness (straggler draws, dropout draws,
compute jitter) is host-side numpy, seeded from ``(seed, step)`` with a
``SeedSequence`` — the same scenario config always produces the same
trajectory, bit for bit.

Cost model (formulas also in docs/simulator.md):

    compute_w = compute_ms * jitter_w * (straggler_scale if straggling)
    comm_w    = sent_bytes_w / bw_w + recv_bytes_w / bw_w
    t_step    = max over ACTIVE workers (compute_w + comm_w)
                + server_bytes / server_bw          (param_server only)
                + hops * latency_ms

with per-worker full-duplex link bandwidth ``bw_w`` (heterogeneous when
``bandwidth_gbps`` is a tuple) and one shared server link.  Dropped
workers spend no time (they are absent for the step) and their payloads
are excluded from the aggregate by the topology layer.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """One logical cluster: link speeds, stragglers, dropout."""

    num_workers: int = 4
    # per-worker link bandwidth; scalar = homogeneous, tuple = one entry
    # per worker (cycled if shorter than num_workers)
    bandwidth_gbps: float | tuple = 10.0
    server_bandwidth_gbps: float = 40.0   # param-server ingress+egress link
    compute_ms: float = 10.0              # base per-step gradient compute
    compute_jitter: float = 0.0           # lognormal sigma on compute time
    straggler_prob: float = 0.0           # P[worker straggles this step]
    straggler_scale: float = 1.0          # compute multiplier when straggling
    dropout_prob: float = 0.0             # P[worker absent this step]
    latency_ms: float = 0.05              # per serialized hop
    seed: int = 0

    def __post_init__(self):
        if self.num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        if self.straggler_scale < 1.0:
            raise ValueError("straggler_scale must be >= 1 (it multiplies "
                             "compute time)")


def worker_bandwidths(cfg: ClusterConfig) -> np.ndarray:
    """(M,) per-worker link bandwidth in bytes/ms."""
    bw = cfg.bandwidth_gbps
    if np.isscalar(bw):
        per = np.full(cfg.num_workers, float(bw))
    else:
        per = np.array([float(bw[i % len(bw)])
                        for i in range(cfg.num_workers)])
    # 1 Gb/s = 1e9 bits/s = 1.25e5 bytes/ms
    return per * 1.25e5


def _rng(cfg: ClusterConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xC1A5]))


def sample_step(cfg: ClusterConfig, step: int):
    """Deterministic per-step draw -> (compute_ms (M,), active (M,) f32).

    Uses one uniform per worker per effect so the draws are COUPLED
    across config changes: raising ``straggler_prob`` or
    ``straggler_scale`` at a fixed seed can only slow workers down,
    which is what makes the monotonicity property testable.

    Worker 0 never drops: the cluster always has at least one survivor.
    """
    M = cfg.num_workers
    rng = _rng(cfg, step)
    u_straggle = rng.random(M)
    u_drop = rng.random(M)
    jitter = (np.exp(cfg.compute_jitter * rng.standard_normal(M))
              if cfg.compute_jitter > 0 else np.ones(M))

    straggling = u_straggle < cfg.straggler_prob
    factor = np.where(straggling, cfg.straggler_scale, 1.0)
    compute = cfg.compute_ms * jitter * factor

    active = (u_drop >= cfg.dropout_prob).astype(np.float32)
    active[0] = 1.0
    return compute, active


def step_time_ms(
    cfg: ClusterConfig,
    compute_ms: np.ndarray,
    active: np.ndarray,
    sent_bytes: np.ndarray,
    recv_bytes: np.ndarray,
    server_bytes: float,
    hops: int,
) -> float:
    """Simulated wall-clock of one bulk-synchronous step (formula above)."""
    bw = worker_bandwidths(cfg)
    comm = (np.asarray(sent_bytes) + np.asarray(recv_bytes)) / bw
    per_worker = np.asarray(compute_ms) + comm
    mask = np.asarray(active) > 0
    makespan = float(per_worker[mask].max()) if mask.any() else 0.0
    server = float(server_bytes) / (cfg.server_bandwidth_gbps * 1.25e5)
    return makespan + server + float(hops) * cfg.latency_ms

"""Declarative scenario registry + the simulated training loop.

A scenario is (scheme grid) x (topology grid) x (cluster model) x (model
config): each cell trains the real model (``repro.models`` +
``repro.train`` optimizers) for ``steps`` simulated steps with M logical
workers on one host, threading genuine ``SchemeState`` adaptation
(merged sufficient statistics across the simulated workers, the paper's
Algorithm 1 line 4) through the chosen aggregation topology, and records
a per-step trajectory: loss, wire bytes by direction, simulated
wall-clock from the cluster cost model, end-to-end aggregate error, and
gradient-statistics drift.

The per-worker protocol is the paper's own evaluation setup (Sec. 5:
"simulate training with M GPUs on a single GPU"), upgraded from plain
ENCODE/DECODE to full topology semantics: stragglers, dropout, and
per-hop re-quantization actually shape what the optimizer sees.

Everything is deterministic in the scenario config: model init, data,
quantization randomness, and cluster draws all derive from fixed seeds,
so the same scenario always emits a bit-identical trajectory (tested).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.compress import CompressionAlgorithm, make_algorithm
from repro.core.codec import (
    EntropyCodec,
    GradientCodec,
    MixedWidthCodec,
    codec_for_scheme,
    entropy_codec_from_gradient,
    mixed_widths_from_gradient,
)
from repro.core.schemes import QuantScheme, SchemeState
from repro.core.stats import expected_variance
from repro.dist.faults import FaultModel
from repro.dist.sync import gather_stats
from repro.models import Model
from repro.train.data import DataConfig, Pipeline
from repro.train.optim import OptimConfig, OptState, apply_updates, init_opt_state

from .cluster import (
    ClusterConfig,
    init_cluster_state,
    sample_step,
    step_faults,
    step_time_ms,
)
from .topology import SIM_AXIS, TOPOLOGIES, run_compressed


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named scenario grid (see SCENARIOS for the registry)."""

    name: str
    description: str = ""
    arch: str = "paper-proxy"
    # scheme specs: "alq" or "alq:4" (name:bits) — the grid's rows
    schemes: tuple = ("alq", "qsgdinf")
    topologies: tuple = TOPOLOGIES
    bits: int = 3
    bucket_size: int = 512
    steps: int = 10
    batch_per_worker: int = 2
    seq_len: int = 32
    lr: float = 1e-3
    optimizer: str = "adamw"
    update_milestones: tuple = (2, 6)   # level-adaptation steps
    sync_mode: str = "all_gather"       # allreduce topology wire mode
    server_bits: int | None = 8         # param_server downlink grid
    norm_dtype: str = "float32"
    # 'uniform' | 'mixed_width' | 'entropy' (the entropy-coded payload
    # family: canonical-Huffman table fit from a probe-step gradient's
    # level occupancies, RE-fit at every level-update milestone so the
    # table tracks the adapting grid — the measured wire bits/coord in
    # the trajectory then converge onto the metered
    # entropy_bits_per_coord)
    codec: str = "uniform"
    # static per-bucket scheme-bits pattern for the mixed-width codec;
    # empty = derive from a probe-step bit assignment (assign_mixed_widths
    # on the probe gradient's bucket statistics, budget = scheme bits).
    # Without an explicit pattern the assignment is RE-derived at every
    # level-update milestone, so the widths track drifting bucket stats.
    mixed_width_pattern: tuple = ()
    # compression-algorithm specs (repro.compress) — the grid's third
    # axis, crossed with schemes x topologies: 'plain' | 'ef[:warmup]'
    # | 'topk[:k]'
    compress: tuple = ("plain",)
    cluster: ClusterConfig = ClusterConfig()
    # opt-in wire integrity: every cell's codec lays per-bucket checksum
    # words into the payload and sync excludes detected-corrupt buckets
    # (core.codec ``integrity=``); requires a uniform/entropy codec
    integrity: bool = False
    # fault-model grid axis, crossed with schemes x topologies x
    # compress: each entry is a ``dist.faults.FaultModel`` or ``None``
    # (fault-free).  Wire faults (flips/drops/delays) hit the allreduce
    # collective through a FaultyTransport; crash/rejoin steps the
    # host-side Markov chain (``cluster.step_faults``) whose staleness
    # weights feed the MaskedTransport renormalization.
    fault_grid: tuple = (None,)
    seed: int = 0

    def make_scheme(self, spec: str) -> QuantScheme:
        name, _, b = spec.partition(":")
        return QuantScheme(
            name=name, bits=int(b) if b else self.bits,
            bucket_size=self.bucket_size, norm_dtype=self.norm_dtype)


SCENARIOS: dict[str, Scenario] = {}


def register(s: Scenario) -> Scenario:
    if s.name in SCENARIOS:
        raise ValueError(f"duplicate scenario {s.name!r}")
    SCENARIOS[s.name] = s
    return s


register(Scenario(
    name="paper_mlp",
    description="ALQ vs QSGDinf on the paper-scale proxy across all three "
                "topologies, homogeneous 4-worker cluster (the acceptance "
                "grid; also the CI smoke scenario).",
))
register(Scenario(
    name="stragglers",
    description="One-in-four steps a worker computes 4x slower: adaptive "
                "schemes keep their accuracy edge while every topology's "
                "simulated throughput degrades.",
    schemes=("alq", "qsgdinf"),
    cluster=ClusterConfig(straggler_prob=0.25, straggler_scale=4.0),
))
register(Scenario(
    name="hetero_bandwidth",
    description="Per-worker link speeds spanning 8x (2.5..20 Gb/s): "
                "param_server funnels through the server link while "
                "ring is gated by the slowest hop.",
    cluster=ClusterConfig(bandwidth_gbps=(2.5, 5.0, 10.0, 20.0)),
))
register(Scenario(
    name="dropout",
    description="Workers vanish for a step with p=0.2; aggregates "
                "renormalize over survivors (worker 0 never drops).",
    schemes=("alq",),
    cluster=ClusterConfig(dropout_prob=0.2),
))
register(Scenario(
    name="mixed_bits",
    description="Width sweep on the allreduce topology: the scheme grid "
                "crosses ALQ/QSGDinf with 2- and 4-bit grids.",
    schemes=("alq:2", "alq:4", "qsgdinf:2", "qsgdinf:4"),
    topologies=("allreduce",),
))
register(Scenario(
    name="ring_compounding",
    description="8-worker ring vs flat allreduce: per-hop re-quantization "
                "compounds error with ring distance; fp32 is the exact "
                "baseline.",
    schemes=("alq", "qsgdinf", "fp32"),
    topologies=("ring", "allreduce"),
    cluster=ClusterConfig(num_workers=8),
    steps=8,
))
register(Scenario(
    name="fp16_norms",
    description="The fp16 bucket-norm wire option end to end: identical "
                "grid to paper_mlp but with half-width norm side-channel.",
    norm_dtype="float16",
))
register(Scenario(
    name="mixed_width",
    description="MixedWidthCodec end to end: per-bucket wire widths from "
                "a probe-step bit assignment (high-norm/high-variance "
                "buckets get more levels at the scheme's mean-bits "
                "budget), threaded through allreduce and param_server.",
    schemes=("alq", "qsgdinf"),
    topologies=("allreduce", "param_server"),
    codec="mixed_width",
))
register(Scenario(
    name="entropy_coded",
    description="EntropyCodec end to end: the metered entropy cost "
                "realized as actual coded bytes.  The canonical-Huffman "
                "table is fit from a probe-step gradient and re-fit at "
                "every level-update milestone; the cost model bills "
                "makespan by the MEASURED per-bucket coded lengths, so "
                "measured bits/coord drop below the fixed-width plan "
                "and track entropy_bits_per_coord as the grid adapts.  "
                "Error feedback stacks on top unchanged (the ef cells "
                "are bit-exact with ef over the uniform codec).",
    schemes=("alq",),
    topologies=("allreduce", "param_server"),
    compress=("plain", "ef"),
    codec="entropy",
))
register(Scenario(
    name="ef_vs_plain",
    description="Error feedback at a 2-bit uniform grid: the residual "
                "memory re-injects each step's quantization error, so "
                "the CUMULATIVE aggregate error (cum_agg_err) stays "
                "bounded while the stateless 2-bit wire random-walks — "
                "EF's end-of-run cum_agg_err is strictly lower.",
    schemes=("qsgdinf:2",),
    topologies=("allreduce",),
    compress=("plain", "ef"),
    steps=10,
))
register(Scenario(
    name="fault_tolerance",
    description="The production allreduce under injected wire faults "
                "with integrity words on: per-word bit flips (~5% of "
                "buckets hit), whole-payload drops/delays, and a "
                "crash/rejoin Markov chain whose rejoining workers "
                "contribute staleness-weighted payloads.  Detected-"
                "corrupt buckets are excluded and renormalized, so the "
                "faulty cell's end-of-run loss stays within a few "
                "percent of the fault-free cell (acceptance: <= 10%).",
    schemes=("alq",),
    topologies=("allreduce",),
    integrity=True,
    # per-WORD flip probability: a 512-coordinate 3-bit bucket spans 65
    # wire words, so ~5% of buckets catch at least one flipped bit
    fault_grid=(None,
                FaultModel(flip_prob=0.0008, drop_prob=0.01,
                           delay_prob=0.01, crash_prob=0.08,
                           rejoin_prob=0.5, seed=13)),
    steps=10,
))
register(Scenario(
    name="topk_sweep",
    description="Top-k sparsification at the equal-wire-budget default "
                "k (index+value payloads cost what the dense symbols "
                "would): per-step error pays for the dropped support, "
                "but the EF memory keeps the cumulative aggregate error "
                "bounded where the dense stateless wire drifts.",
    schemes=("qsgdinf:2",),
    topologies=("allreduce", "param_server"),
    compress=("plain", "topk"),
    steps=10,
))


# ---------------------------------------------------------------------------
# one grid cell = (scheme, topology) trained for `steps` simulated steps
# ---------------------------------------------------------------------------

def _build_cell_step(model: Model, scheme: QuantScheme, scn: Scenario,
                     topo: str, mesh, use_pallas: bool,
                     algo: CompressionAlgorithm,
                     fault: FaultModel | None = None):
    """Jitted per-step function (runs inside shard_map on the 1x1 mesh so
    the model's internal psum('model') collectives resolve)."""
    M = scn.cluster.num_workers
    ocfg = OptimConfig(name=scn.optimizer, lr=scn.lr, weight_decay=0.0)
    pspecs = model.param_specs()
    # no dropout and no crash/rejoin -> active is statically all-ones;
    # passing None keeps the topologies on the exact production
    # reduction order (mean(0)).  Crash/rejoin staleness weights are
    # FRACTIONAL actives, so they also need the masked transport.
    masked = (scn.cluster.dropout_prob > 0
              or (fault is not None and fault.crash_prob > 0))

    def step(params, mu, nu, count, levels, multiplier, num_updates,
             ent_bits, resid, cstep, cum_err, ids, labels, key,
             do_update, active, fault_step):
        from repro.compress import CompressState
        scheme_state = SchemeState(levels, multiplier, num_updates,
                                   ent_bits)
        comp_state = CompressState(residual=resid, step=cstep)
        per = ids.shape[0] // M

        def worker_grad(w):
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, w * per, per)
            loss, g = jax.value_and_grad(
                lambda p: model.loss(p, {"ids": sl(ids),
                                         "labels": sl(labels)}))(params)
            flat, _ = ravel_pytree(g)
            return loss, flat

        losses, flats = jax.lax.map(worker_grad, jnp.arange(M))

        res, comp_state = run_compressed(
            topo, flats, scheme, scheme_state, algo, comp_state, key,
            active=active if masked else None,
            sync_mode=scn.sync_mode, server_bits=scn.server_bits,
            use_pallas=use_pallas, fault=fault, fault_step=fault_step)

        # end-to-end aggregate error vs the exact (masked) fp32 mean —
        # the metric where ring's per-hop compounding becomes visible
        if masked:
            wmask = active / jnp.maximum(jnp.sum(active), 1.0)
            exact = jnp.tensordot(wmask, flats, axes=(0, 0))
        else:
            exact = flats.mean(0)
        agg = res.aggregate[0]
        agg_err = jnp.sum((agg - exact) ** 2)
        # cumulative aggregate-error VECTOR: the metric error feedback
        # bounds (sum_t agg_t - sum_t exact_t random-walks for stateless
        # wires; EF's residual telescopes it down to the final memory)
        cum_err = cum_err + (agg - exact)
        cum_agg_err = jnp.sum(cum_err ** 2)
        residual_norm = jnp.mean(jax.vmap(algo.residual_norm)(comp_state))

        # Algorithm 1 line 4 on the simulated cluster: sufficient
        # statistics merged ACROSS the M logical workers (vmap axes are
        # named axes, so merge_stats runs its real all_gather)
        if scheme.adaptive:
            def upd(s):
                stats = jax.vmap(
                    lambda f: gather_stats(f, scheme, axes=(SIM_AXIS,),
                                           use_pallas=use_pallas),
                    axis_name=SIM_AXIS)(flats)
                return scheme.update_state(
                    s, jax.tree.map(lambda a: a[0], stats))
            scheme_state = jax.lax.cond(do_update, upd, lambda s: s,
                                        scheme_state)

        # gradient-statistics drift: pooled truncated-normal fit of
        # worker 0's normalized magnitudes + the paper's Psi objective
        # evaluated at the CURRENT levels
        stats_now = gather_stats(flats[0], scheme, axes=(),
                                 use_pallas=use_pallas)
        drift_mu = jnp.sum(stats_now.gamma * stats_now.mu)
        drift_sigma = jnp.sum(stats_now.gamma * stats_now.sigma)
        psi = expected_variance(stats_now, scheme_state.levels)

        _, unravel = ravel_pytree(params)
        nu_state = nu if ocfg.name == "adamw" else None
        new_params, new_opt = apply_updates(
            ocfg, params, unravel(agg), OptState(mu, nu_state, count))
        new_nu = new_opt.nu if new_opt.nu is not None else nu

        metrics = {
            "loss": jnp.mean(losses),
            "agg_err": agg_err,
            "cum_agg_err": cum_agg_err,
            "quant_error": jnp.mean(res.quant_error),
            "residual_norm": residual_norm,
            "kept_fraction": jnp.float32(algo.kept_fraction),
            "grad_norm": jnp.sqrt(jnp.sum(exact ** 2)),
            "sent_bytes": res.sent_bytes,
            "recv_bytes": res.recv_bytes,
            "server_bytes": res.server_bytes,
            "hops": res.hops,
            "drift_mu": drift_mu,
            "drift_sigma": drift_sigma,
            "psi": psi,
            "levels": scheme_state.levels,
            "entropy_bits_per_coord": scheme_state.entropy_bits,
            # worker 0's shipped wire bits/coord (both directions):
            # MEASURED from the coded-length headers for the entropy
            # payload family, the static plan otherwise
            "measured_bits_per_coord": jnp.asarray(
                res.wire_bits_per_coord, jnp.float32)[0],
            "corrupt_fraction": jnp.asarray(res.corrupt_fraction,
                                            jnp.float32),
            "excluded_workers": jnp.asarray(res.excluded_workers,
                                            jnp.float32),
        }
        return (new_params, new_opt.mu, new_nu, new_opt.count,
                scheme_state.levels, scheme_state.multiplier,
                scheme_state.num_updates, scheme_state.entropy_bits,
                comp_state.residual, comp_state.step, cum_err,
                metrics)

    smapped = jax.shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, pspecs, pspecs, P(), P(), P(), P(), P(),
                  P(), P(), P(), P(), P(), P(), P(), P(), P()),
        out_specs=(pspecs, pspecs, pspecs, P(), P(), P(), P(), P(),
                   P(), P(), P(),
                   {k: P() for k in ("loss", "agg_err", "cum_agg_err",
                                     "quant_error", "residual_norm",
                                     "kept_fraction", "grad_norm",
                                     "sent_bytes", "recv_bytes",
                                     "server_bytes", "hops",
                                     "drift_mu", "drift_sigma", "psi",
                                     "levels", "entropy_bits_per_coord",
                                     "measured_bits_per_coord",
                                     "corrupt_fraction",
                                     "excluded_workers")}),
        check_vma=False)
    return jax.jit(smapped), ocfg


def _probe_gradient(model: Model, mesh, params, batch,
                    per_worker: int) -> jnp.ndarray:
    """Worker 0's probe-step gradient: one real backward on the first
    batch shard — the raw material of every host-level codec fit (the
    mixed-width bit assignment and the entropy-table refit)."""
    pspecs = model.param_specs()

    def gradf(p, ids, labels):
        g = jax.grad(lambda q: model.loss(
            q, {"ids": ids, "labels": labels}))(p)
        flat, _ = ravel_pytree(g)
        return flat

    f = jax.jit(jax.shard_map(
        gradf, mesh=mesh, in_specs=(pspecs, P(), P()), out_specs=P(),
        check_vma=False))
    with jax.set_mesh(mesh):
        return f(params, batch["ids"][:per_worker],
                 batch["labels"][:per_worker])


def _probe_mixed_widths(model: Model, scheme: QuantScheme, mesh,
                        params, batch, per_worker: int) -> tuple:
    """Per-bucket bit assignment from the probe gradient
    (``codec.mixed_widths_from_gradient``) — the static width pattern
    the whole cell then runs on."""
    flat = _probe_gradient(model, mesh, params, batch, per_worker)
    return mixed_widths_from_gradient(flat, scheme)


def _probe_entropy_codec(model: Model, scheme: QuantScheme, mesh,
                         params, batch, per_worker: int,
                         levels) -> EntropyCodec:
    """Canonical-Huffman table from the probe gradient's level
    occupancies at the CURRENT grid
    (``codec.entropy_codec_from_gradient``)."""
    flat = _probe_gradient(model, mesh, params, batch, per_worker)
    return entropy_codec_from_gradient(flat, scheme, levels)


def _make_cell_codec(scn: Scenario, scheme: QuantScheme, model: Model,
                     mesh, params, batch) -> GradientCodec | None:
    if not scheme.quantized:
        return None
    if scn.codec == "uniform":
        if not scn.integrity:
            return None          # default codec: exact production path
        return dataclasses.replace(codec_for_scheme(scheme),
                                   integrity=True)
    if scn.codec == "entropy":
        codec = _probe_entropy_codec(model, scheme, mesh, params, batch,
                                     scn.batch_per_worker,
                                     scheme.init_levels())
        if scn.integrity:
            codec = dataclasses.replace(codec, integrity=True)
        return codec
    if scn.codec != "mixed_width":
        raise ValueError(f"unknown scenario codec {scn.codec!r}")
    if scn.integrity:
        raise ValueError(
            "integrity=True needs a per-bucket checksum slot; the "
            "mixed-width payload family has none (use 'uniform' or "
            "'entropy')")
    widths = scn.mixed_width_pattern or _probe_mixed_widths(
        model, scheme, mesh, params, batch, scn.batch_per_worker)
    return MixedWidthCodec(bucket_size=scheme.bucket_size,
                           norm_type=scheme.norm_type,
                           norm_dtype=scheme.norm_dtype,
                           widths=tuple(int(b) for b in widths))


def _fixed_bits_per_coord(scn: Scenario, scheme: QuantScheme, topo: str,
                          d: int) -> float:
    """The fixed-width (uniform-codec) counterpart of the trajectory's
    per-worker ``measured_bits_per_coord`` for this topology — the plan
    an entropy-coded cell must beat.  Matches ``TopologyResult
    .wire_bits_per_coord``'s direction accounting: the gather hop for
    allreduce, uplink + downlink for param_server."""
    if not scheme.quantized:
        return 32.0
    from repro.core.codec import requant_codec
    from repro.dist import sync
    uc = codec_for_scheme(scheme)
    plan = uc.plan(d)
    if topo == "param_server":
        if scn.server_bits is None:
            down = 32.0
        else:
            c2 = requant_codec(uc, scn.server_bits)
            down = 8.0 * c2.plan_buckets(plan.nb).payload_bytes / d
        return float(plan.bits_per_coord + down)
    if topo == "ring":
        M = scn.cluster.num_workers
        splan = uc.plan(d, shards=M)
        return float(2.0 * (M - 1) * splan.payload_bytes * 8.0 / d)
    if scn.sync_mode == "two_phase":
        # reduce hop (scheme grid, sharded) + 8-bit broadcast hop —
        # the same two-hop sum _allreduce_two_phase reports
        M = scn.cluster.num_workers
        splan = uc.plan(d, shards=M)
        p2 = requant_codec(uc, sync.TWO_PHASE_BITS).plan_buckets(
            splan.shard_nb)
        return float(splan.bits_per_coord
                     + 32.0 * (p2.code_words + p2.norm_words) / d)
    return float(plan.bits_per_coord)


def _run_cell(scn: Scenario, spec: str, topo: str, comp_spec: str,
              steps: int, use_pallas: bool,
              fault: FaultModel | None = None) -> dict[str, Any]:
    scheme = scn.make_scheme(spec)
    cfg = configs.get_config(scn.arch)
    M = scn.cluster.num_workers
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = Model(cfg, tp=1, dp=1)
    pipe = Pipeline(DataConfig(
        kind="markov", vocab_size=cfg.vocab_size, seq_len=scn.seq_len,
        global_batch=scn.batch_per_worker * M, seed=scn.seed))

    with jax.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(scn.seed))
    codec = _make_cell_codec(scn, scheme, model, mesh, params,
                             pipe.batch(0))
    algo = make_algorithm(comp_spec, scheme, codec=codec)
    step_fn, ocfg = _build_cell_step(model, scheme, scn, topo, mesh,
                                     use_pallas, algo, fault=fault)
    opt = init_opt_state(ocfg, params)
    state = scheme.init_state()

    mu, nu, count = opt.mu, opt.nu, opt.count
    if nu is None:
        nu = jax.tree.map(jnp.zeros_like, mu)
    levels, mult, n_upd = state.levels, state.multiplier, state.num_updates
    ent = state.entropy_bits

    d = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    dres = d if algo.stateful else 0
    resid = jnp.zeros((M, dres), jnp.float32)
    cstep = jnp.zeros((M,), jnp.int32)
    cum_err = jnp.zeros((d,), jnp.float32)

    # widths / entropy tables are static (trace-time) layout, so
    # tracking drifting bucket stats happens at the HOST level: on every
    # level-update milestone the probe protocol re-runs on the current
    # parameters' gradient and the cell is re-built on the fresh
    # assignment (same cadence as ``maybe_update_levels``)
    reassign = (scn.codec == "mixed_width" and scheme.quantized
                and not scn.mixed_width_pattern)
    refit_table = scn.codec == "entropy" and scheme.quantized
    width_reassignments: list[dict[str, Any]] = []
    table_refits: list[dict[str, Any]] = []

    traj = []
    sim_time = 0.0
    wire_total = 0.0
    fault_events: list[dict[str, Any]] = []
    cstate = (init_cluster_state(M)
              if fault is not None and fault.crash_prob > 0 else None)
    with jax.set_mesh(mesh):
        for t in range(steps):
            batch = pipe.batch(t)
            compute_ms, active = sample_step(scn.cluster, t)
            if cstate is not None:
                # crash/rejoin Markov chain: crashed workers contribute
                # weight 0, rejoining ones the staleness weight 1/(1+k)
                # — fractional actives through the MaskedTransport
                cstate, fweight, events = step_faults(fault, cstate, t)
                active = active * fweight
                fault_events.extend(events)
            key = jax.random.fold_in(jax.random.PRNGKey(scn.seed + 7), t)
            (params, mu, nu, count, levels, mult, n_upd, ent,
             resid, cstep, cum_err, m) = step_fn(
                params, mu, nu, count, levels, mult, n_upd, ent,
                resid, cstep, cum_err,
                batch["ids"], batch["labels"], key,
                jnp.bool_(t in scn.update_milestones),
                jnp.asarray(active), jnp.int32(t))
            if reassign and t in scn.update_milestones:
                new_widths = _probe_mixed_widths(
                    model, scheme, mesh, params, batch,
                    scn.batch_per_worker)
                changed = tuple(new_widths) != tuple(codec.widths)
                width_reassignments.append({
                    "step": t,
                    "changed": changed,
                    "mean_width": float(np.mean(new_widths)),
                    "widths": [int(b) for b in new_widths],
                })
                if changed:
                    codec = dataclasses.replace(
                        codec, widths=tuple(int(b) for b in new_widths))
                    algo = make_algorithm(comp_spec, scheme, codec=codec)
                    step_fn, _ = _build_cell_step(
                        model, scheme, scn, topo, mesh, use_pallas, algo,
                        fault=fault)
            if refit_table and t in scn.update_milestones:
                # the levels just adapted inside step_fn: re-fit the
                # canonical-Huffman table to the NEW grid's occupancies
                # on a fresh probe gradient and rebuild the cell on it
                new_codec = _probe_entropy_codec(
                    model, scheme, mesh, params, batch,
                    scn.batch_per_worker, levels)
                changed = (new_codec.huff_lengths != codec.huff_lengths
                           or new_codec.huff_codes != codec.huff_codes)
                table_refits.append({
                    "step": t,
                    "changed": changed,
                    "max_code_bits": max(new_codec.huff_lengths),
                    "code_lengths": [int(l)
                                     for l in new_codec.huff_lengths],
                })
                if changed:
                    codec = new_codec
                    algo = make_algorithm(comp_spec, scheme, codec=codec)
                    step_fn, _ = _build_cell_step(
                        model, scheme, scn, topo, mesh, use_pallas, algo,
                        fault=fault)
            sent = np.asarray(m["sent_bytes"], np.float64)
            recv = np.asarray(m["recv_bytes"], np.float64)
            server = float(m["server_bytes"])
            hops = int(m["hops"])
            dt = step_time_ms(scn.cluster, compute_ms, active, sent, recv,
                              server, hops)
            if fault is not None and fault.delay_prob > 0:
                # a delayed payload stalls the aggregation window: bill
                # delay_ms once if any surviving worker's payload is late
                delayed = np.asarray(fault.delayed_workers(t, M))
                if bool(delayed[np.asarray(active) > 0].any()):
                    dt += fault.delay_ms
            sim_time += dt
            # total bytes crossing worker NICs (uniform across topologies;
            # the server's own link shows up in recv, not double-counted)
            step_wire = float(((sent + recv) * (active > 0)).sum())
            wire_total += step_wire
            traj.append({
                "step": t,
                "loss": float(m["loss"]),
                "sim_time_ms": dt,
                "cum_sim_time_ms": sim_time,
                "wire_sent_bytes": sent.tolist(),
                "wire_recv_bytes": recv.tolist(),
                "server_bytes": server,
                "hops": hops,
                "agg_err": float(m["agg_err"]),
                "cum_agg_err": float(m["cum_agg_err"]),
                "quant_error": float(m["quant_error"]),
                "residual_norm": float(m["residual_norm"]),
                "kept_fraction": float(m["kept_fraction"]),
                "grad_norm": float(m["grad_norm"]),
                "drift_mu": float(m["drift_mu"]),
                "drift_sigma": float(m["drift_sigma"]),
                "psi": float(m["psi"]),
                "entropy_bits_per_coord": float(
                    m["entropy_bits_per_coord"]),
                "measured_bits_per_coord": float(
                    m["measured_bits_per_coord"]),
                "levels": np.asarray(m["levels"]).tolist(),
                "compute_ms": np.asarray(compute_ms).tolist(),
                "active": [bool(a > 0) for a in active],
                "active_weight": [float(a) for a in np.asarray(active)],
                "corrupt_fraction": float(m["corrupt_fraction"]),
                "excluded_workers": float(m["excluded_workers"]),
            })
    return {
        "scheme": spec,
        "topology": topo,
        "compress": comp_spec,
        "bits": scheme.bits,
        "norm_dtype": scheme.norm_dtype,
        "codec": scn.codec if scheme.quantized else "uniform",
        "kept_fraction": float(algo.kept_fraction),
        "mean_width": (codec.mean_scheme_bits
                       if isinstance(codec, MixedWidthCodec)
                       else float(scheme.bits)),
        "width_reassignments": width_reassignments,
        "table_refits": table_refits,
        "integrity": bool(scn.integrity and scheme.quantized),
        "fault": dataclasses.asdict(fault) if fault is not None else None,
        "fault_events": fault_events,
        "fixed_bits_per_coord": _fixed_bits_per_coord(scn, scheme, topo,
                                                      d),
        "steps": traj,
        "totals": {
            "sim_time_ms": sim_time,
            "wire_bytes": wire_total,
            "final_loss": traj[-1]["loss"] if traj else None,
            "mean_agg_err": (float(np.mean([s["agg_err"] for s in traj]))
                             if traj else None),
            "final_cum_agg_err": (traj[-1]["cum_agg_err"] if traj
                                  else None),
            "mean_corrupt_fraction": (
                float(np.mean([s["corrupt_fraction"] for s in traj]))
                if traj else None),
        },
    }


def run_scenario(scn: Scenario, *, steps: int | None = None,
                 workers: int | None = None,
                 use_pallas: bool = False) -> dict[str, Any]:
    """Run every (scheme, topology, compress) cell of a scenario;
    JSON-ready dict."""
    if workers is not None:
        scn = dataclasses.replace(
            scn, cluster=dataclasses.replace(scn.cluster,
                                             num_workers=workers))
    n_steps = steps if steps is not None else scn.steps
    cells = []
    for spec in scn.schemes:
        for topo in scn.topologies:
            for comp in scn.compress:
                for fault in (scn.fault_grid or (None,)):
                    cells.append(_run_cell(scn, spec, topo, comp,
                                           n_steps, use_pallas,
                                           fault=fault))
    out = {
        "scenario": scn.name,
        "description": scn.description,
        "config": dataclasses.asdict(scn),
        "num_steps": n_steps,
        "cells": cells,
    }
    return out

"""Aggregation topologies for the cluster simulator.

Three ways to turn M per-worker gradients into an aggregate, all behind
one interface (``run_topology``) and all speaking the packed
``core.codec.WirePayload`` wire format:

``allreduce``     The production path, verbatim: M logical workers run
    ``repro.dist.sync.quantized_allreduce`` under ``jax.vmap`` with a
    named axis (vmap axes are real named axes, so the collectives inside
    the wire modes execute unmodified).  Worker dropout is injected via
    ``dist.transport.MaskedTransport``.

``param_server``  The classic QSGD worker/server split: every worker
    ENCODEs through the codec and ships its payload up; the server
    DECODEs the surviving payloads, averages, optionally RE-quantizes
    the aggregate on a fixed uniform/L-inf grid (``server_bits``), and
    broadcasts one payload down.  With ``server_bits=None`` the server
    broadcasts raw fp32 — in that case a homogeneous cluster is
    bit-identical to ``allreduce`` in ``all_gather`` mode, because both
    reduce to "decode all M streams, average" with the same per-worker
    encode keys (tested).

``ring``          Chunked ring allreduce with PER-HOP re-quantization:
    the gradient splits into M whole-bucket chunks; M-1 reduce hops pass
    accumulating partial sums around the ring, each hop re-encoded via
    ``codec.requantize``, then M-1 gather hops circulate the finished
    chunks, again re-encoded per hop.  The injected noise therefore
    compounds with ring distance — the error-vs-topology effect the
    paper's flat broadcast scheme avoids, made measurable
    (``quant_error`` records each worker's injected noise; scenario
    trajectories record the end-to-end aggregate error).

All three are deterministic functions of (grads, scheme state, key):
worker-distinct randomness comes from folding worker rank / hop index
into the replicated key, exactly like the production collectives.  A
``MixedWidthCodec`` rides every topology: chunk/shard layouts come from
the codec's static plan — as does the ``SparseCodec`` top-k payload
family.  ``run_compressed`` wraps any topology in the ``repro.compress``
algorithm hook, threading M per-worker error-feedback residuals.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import GradientCodec, codec_for_scheme, requant_codec
from repro.core.levels import uniform_levels
from repro.core.schemes import QuantScheme, SchemeState
from repro.dist import sync
from repro.dist.faults import FaultModel, faulty
from repro.dist.transport import MaskedTransport, make_transport

# the vmap axis name the simulator runs its logical workers on
SIM_AXIS = "sim_workers"

TOPOLOGIES = ("allreduce", "param_server", "ring")


class TopologyResult(NamedTuple):
    """What one synchronization round produced, per worker.

    ``aggregate`` is each worker's *view* of the aggregate — identical
    rows for allreduce/param_server, per-worker divergent for ring
    (downstream copies of a chunk pass through more re-quantizations).
    Byte counts feed the cluster cost model (``sim.cluster``).
    """

    aggregate: jnp.ndarray         # (M, d)
    sent_bytes: jnp.ndarray        # (M,) transmitted by worker w
    recv_bytes: jnp.ndarray        # (M,) received by worker w
    server_bytes: jnp.ndarray      # () through the server (0 if none)
    hops: jnp.ndarray              # () latency-serialized hops
    quant_error: jnp.ndarray       # (M,) own injected quantization noise
    own: jnp.ndarray | None = None  # (M, d) each worker's own lossy
    #   round trip Q(input) — the repro.compress feedback signal; only
    #   populated under run_topology(want_own=True)
    wire_bits_per_coord: jnp.ndarray = jnp.float32(0.0)  # (M,) per-worker
    #   shipped wire bits per original coordinate, summed over both
    #   directions of the worker's own traffic — MEASURED for
    #   variable-volume codecs (the entropy payload family), planned
    #   otherwise.  What the entropy_coded scenario charts against the
    #   metered entropy_bits_per_coord.
    corrupt_fraction: jnp.ndarray = jnp.float32(0.0)  # () fraction of
    #   (worker, bucket) wire slots that failed integrity checks this
    #   round and were excluded (allreduce topology under a FaultModel
    #   with integrity= plans; 0 everywhere else)
    excluded_workers: jnp.ndarray = jnp.float32(0.0)  # () workers whose
    #   whole payload failed integrity this round


# ---------------------------------------------------------------------------
# allreduce: the production collective under vmap
# ---------------------------------------------------------------------------

def _topo_allreduce(grads, scheme, state, key, active, *, mode, codec,
                    use_pallas, want_own=False, fault=None,
                    fault_step=0):
    """``active=None`` (statically homogeneous) uses the default
    ``MeshTransport`` — the production ``stacked.mean(0)`` reduction
    order, bit for bit; a mask switches to the renormalizing
    ``MaskedTransport``.  A ``FaultModel`` with wire faults wraps
    whichever transport in a ``FaultyTransport`` keyed on
    ``(fault.seed, fault_step)`` — the real ENCODE -> collective ->
    DECODE path then runs under injected corruption."""
    M, d = grads.shape
    inject = fault is not None and fault.any_wire_faults

    def worker(g):
        transport = (MaskedTransport((SIM_AXIS,), active)
                     if active is not None else None)
        if inject:
            transport = faulty(
                transport if transport is not None
                else make_transport((SIM_AXIS,)),
                fault, fault_step)
        return sync.quantized_allreduce(
            g, scheme, state, key, axes=(SIM_AXIS,), mode=mode,
            use_pallas=use_pallas, transport=transport, codec=codec,
            return_own=True)

    out, own, m = jax.vmap(worker, axis_name=SIM_AXIS)(grads)

    # byte accounting from the per-direction metrics (bits are per
    # original coordinate; padding is already folded in by sync)
    scale = d / 8.0
    if mode == "two_phase":
        # phase 1 all-to-all ships each peer its shard; phase 2 gathers
        # the re-quantized shard payload from every worker
        p1 = m.reduce_bits_per_coord * scale
        p2 = m.broadcast_bits_per_coord * scale
        sent = (M - 1) / M * p1 + (M - 1) * p2
        recv = (M - 1) / M * p1 + (jnp.sum(p2) - p2)
        hops = 2
    elif mode == "fp32" or not scheme.quantized:
        # cost as a bandwidth-optimal fp32 ring (2(M-1)/M · 4d each way)
        vol = 2 * (M - 1) / M * 4.0 * d
        sent = jnp.full((M,), vol, jnp.float32)
        recv = sent
        hops = 2
    else:
        # broadcast-all gather: each worker ships its payload to M-1 peers
        p = m.broadcast_bits_per_coord * scale
        sent = (M - 1) * p
        recv = jnp.sum(p) - p
        hops = 1
    return TopologyResult(out, sent, recv, jnp.float32(0.0),
                          jnp.int32(hops), m.quant_error,
                          own if want_own else None,
                          jnp.asarray(m.comm_bits_per_coord,
                                      jnp.float32),
                          corrupt_fraction=jnp.asarray(
                              m.corrupt_fraction, jnp.float32)[0],
                          excluded_workers=jnp.asarray(
                              m.excluded_workers, jnp.float32)[0])


# ---------------------------------------------------------------------------
# param_server: encode up, decode/average/(re-quantize), broadcast down
# ---------------------------------------------------------------------------

def _topo_param_server(grads, scheme, state, key, active,
                       *, server_bits, codec, use_pallas,
                       want_own=False):
    M, d = grads.shape
    levels = state.levels
    plan = codec.plan(d)

    # ---- uplink: per-worker encode with the production key schedule ----
    vb = jax.vmap(lambda g: codec.bucketize(g, plan))(grads)
    keys = jax.vmap(lambda w: jax.random.fold_in(key, w))(jnp.arange(M))
    payloads = jax.vmap(
        lambda v, k: codec.encode(v, levels, k, plan,
                                  use_pallas=use_pallas))(vb, keys)

    # ---- server: decode surviving payloads, weighted average ----
    # (active=None -> .mean(0): the same float reduction order as the
    # production allreduce, preserving bit-exactness with it)
    per_worker = codec.decode(payloads, levels, plan,
                              use_pallas=use_pallas)       # (M, n)
    if active is None:
        agg = per_worker.mean(0)
    else:
        w = active / jnp.maximum(jnp.sum(active), 1.0)
        agg = jnp.tensordot(w, per_worker, axes=(0, 0))  # (n,)

    # uplink bytes: what each worker's payload actually needs to ship —
    # measured from the coded-length headers for variable-volume codecs
    # (the entropy payload family), the static plan otherwise (the
    # static branch keeps the pre-entropy accounting bit-identical)
    if plan.variable:
        up = jax.vmap(
            lambda p: codec.measured_bits_per_coord(p, plan))(
                payloads) * (d / 8.0)
    else:
        up = jnp.full((M,), plan.payload_bytes, jnp.float32)
    own = per_worker[:, :d]
    qerr = jnp.sum((own - grads) ** 2, axis=1)

    # ---- downlink: one payload, every worker decodes the same bytes ----
    if server_bits is None:
        out = jnp.broadcast_to(agg[None, :d], (M, d))
        down = jnp.float32(4.0 * d)                 # raw fp32 broadcast
    else:
        codec2 = requant_codec(codec, server_bits)
        lv2 = uniform_levels(server_bits)
        plan2 = codec2.plan_buckets(plan.nb)
        pay2 = codec2.encode(agg.reshape(plan.nb, plan.bucket_size), lv2,
                             jax.random.fold_in(key, M + 0x5E2F), plan2,
                             use_pallas=use_pallas)
        dec = codec2.decode(pay2, lv2, plan2, use_pallas=use_pallas)
        out = jnp.broadcast_to(dec.reshape(-1)[None, :d], (M, d))
        down = jnp.float32(plan2.payload_bytes)

    sent = up
    recv = jnp.full((M,), down, jnp.float32)
    server_bytes = jnp.sum(up) + M * down
    return TopologyResult(out, sent, recv, server_bytes,
                          jnp.int32(2), qerr,
                          own if want_own else None,
                          (up + down) * (8.0 / d))


# ---------------------------------------------------------------------------
# ring: chunked reduce-scatter + all-gather, re-quantized per hop
# ---------------------------------------------------------------------------

def _ring_qhop(x, levels, hop_key, codec, plan, chunk_of_row, use_pallas):
    """One re-quantizing hop: row w of x is worker w's current chunk
    (``chunk_of_row[w]`` — static per hop), re-encoded on the codec's
    grid for that chunk with worker-distinct randomness."""
    M = x.shape[0]
    rows = [codec.requantize(x[w], levels, jax.random.fold_in(hop_key, w),
                             plan, chunk=chunk_of_row[w],
                             use_pallas=use_pallas)
            for w in range(M)]
    return jnp.stack(rows)


def _topo_ring(grads, scheme, state, key, active, *, codec, use_pallas,
               want_own=False):
    M, d = grads.shape
    levels = state.levels
    plan = codec.plan(d, shards=M)

    # Dropout simplification: a dropped worker's *contribution* is
    # zeroed and the sum renormalizes over survivors, but the ring stays
    # closed (no re-formation is simulated) — the cluster layer treats
    # the worker as absent, so its relay traffic is not charged.
    contrib = grads if active is None else grads * active[:, None]
    vb = jax.vmap(lambda g: codec.bucketize(g, plan))(contrib)
    nb = plan.nb
    shard_nb = plan.shard_nb
    bs = plan.bucket_size
    # (M, M, shard_nb, bs): worker w's local chunks
    local = vb.reshape(M, M, shard_nb, bs)
    widx = jnp.arange(M)

    if not scheme.quantized:
        def qhop(x, hop_key, chunks):
            return x
    else:
        def qhop(x, hop_key, chunks):
            return _ring_qhop(x, levels, hop_key, codec, plan, chunks,
                              use_pallas)

    qerr = jnp.zeros((M,), jnp.float32)

    # ---- reduce-scatter: M-1 hops of accumulating partial sums ----
    # before hop h, worker w holds its partial of chunk (w - h) mod M
    acc = local[widx, widx]                       # (M, shard_nb, bs)
    for h in range(M - 1):
        chunks = [(w - h) % M for w in range(M)]
        q = qhop(acc, jax.random.fold_in(key, 0x11A0 + h), chunks)
        qerr = qerr + jnp.sum((q - acc) ** 2, axis=(1, 2))
        incoming = jnp.roll(q, 1, axis=0)         # from worker w-1
        cidx = (widx - 1 - h) % M                 # chunk arriving at w
        acc = incoming + local[widx, cidx]

    # worker w now holds the full sum of chunk (w + 1) mod M
    if active is None:
        weight = 1.0 / M
    else:
        weight = 1.0 / jnp.maximum(jnp.sum(active), 1.0)
    acc = acc * weight                            # sum -> masked mean

    # ---- all-gather: M-1 hops circulating finished chunks ----
    views = jnp.zeros((M, M, shard_nb, bs), acc.dtype)
    own_chunk = (widx + 1) % M
    views = views.at[widx, own_chunk].set(acc)
    cur = acc
    for h in range(M - 1):
        chunks = [(w + 1 - h) % M for w in range(M)]
        q = qhop(cur, jax.random.fold_in(key, 0x22B0 + h), chunks)
        qerr = qerr + jnp.sum((q - cur) ** 2, axis=(1, 2))
        cur = jnp.roll(q, 1, axis=0)              # from worker w-1
        cidx = (widx - h) % M                     # chunk now held by w
        views = views.at[widx, cidx].set(cur)

    out = views.reshape(M, nb * bs)[:, :d]

    own = None
    if want_own:
        # Per-hop re-quantization means worker w's contribution is only
        # ever rounded ALONE at its first hop (chunk w, hop 0); for the
        # compress layer's residual we use the full first-quantization
        # round trip Q(inp_w) — the noise the worker itself injects —
        # re-using the hop-0 key schedule so chunk w matches the wire.
        if not scheme.quantized:
            own = grads
        else:
            k0 = jax.random.fold_in(key, 0x11A0)

            def own_worker(v, w):
                hop_key = jax.random.fold_in(k0, w)
                segs = [codec.requantize(
                    v.reshape(M, shard_nb, bs)[c], levels, hop_key, plan,
                    chunk=c, use_pallas=use_pallas) for c in range(M)]
                return jnp.stack(segs).reshape(-1)[:d]

            own = jnp.stack([own_worker(vb[w], w) for w in range(M)])

    # ring hops re-encode value-space (codec.requantize), so there is no
    # payload to read headers from: variable-volume codecs are billed at
    # capacity here (the ring is not part of the entropy_coded scenario)
    chunk_bytes = plan.payload_bytes
    if not scheme.quantized:
        chunk_bytes = 4.0 * plan.shard_n
    vol = jnp.full((M,), 2.0 * (M - 1) * chunk_bytes, jnp.float32)
    return TopologyResult(out, vol, vol, jnp.float32(0.0),
                          jnp.int32(2 * (M - 1)), qerr, own,
                          vol * (8.0 / d))


# ---------------------------------------------------------------------------
# the one interface the scenario engine calls
# ---------------------------------------------------------------------------

def run_topology(
    name: str,
    grads: jnp.ndarray,
    scheme: QuantScheme,
    state: SchemeState,
    key: jax.Array,
    *,
    active: jnp.ndarray | None = None,
    sync_mode: str = "all_gather",
    server_bits: int | None = sync.TWO_PHASE_BITS,
    codec: GradientCodec | None = None,
    use_pallas: bool = False,
    want_own: bool = False,
    fault: FaultModel | None = None,
    fault_step=0,
) -> TopologyResult:
    """Synchronize (M, d) per-worker gradients over a named topology.

    Args:
      name: 'allreduce' | 'param_server' | 'ring'.
      grads: (M, d) stacked local gradients (M logical workers).
      scheme / state: quantization method + adaptive state, as in
        ``quantized_allreduce``.
      key: replicated PRNG key; worker/hop-distinct randomness is folded
        in internally, matching the production key schedule.
      active: (M,) float mask, 1.0 = worker's payload arrives; ``None``
        means statically homogeneous, which keeps the exact production
        float reduction order (``mean(0)``).  Dropped workers are
        excluded from the aggregate (renormalized mean over survivors).
      sync_mode: wire mode for the allreduce topology (fp32 schemes use
        exact fp32 everywhere regardless).
      server_bits: param_server downlink grid width; ``None`` broadcasts
        raw fp32 (bit-identical to allreduce on a homogeneous cluster).
      codec: wire codec; defaults to the scheme's uniform codec.  A
        ``MixedWidthCodec`` threads per-bucket widths through every
        topology, a ``SparseCodec`` top-k index+value payloads.
      want_own: also populate ``TopologyResult.own`` — each worker's own
        lossy round trip Q(input), the ``repro.compress`` feedback
        signal (free for allreduce/param_server; the ring pays an extra
        local requantize pass).
      fault / fault_step: wire-fault injection (``dist.faults
        .FaultModel``) for the allreduce topology — the production
        collective path runs under a ``FaultyTransport`` keyed on
        ``(fault.seed, fault_step)``.  Only the allreduce topology
        exercises the real ``dist.sync`` wire; requesting wire faults
        on param_server/ring raises rather than silently simulating
        nothing.
    """
    grads = jnp.asarray(grads)
    if active is not None:
        active = jnp.asarray(active, jnp.float32)
    if codec is None:
        codec = codec_for_scheme(scheme)
    if (fault is not None and fault.any_wire_faults
            and name != "allreduce"):
        raise ValueError(
            f"wire-fault injection targets the real dist.sync collective "
            f"(topology 'allreduce'); topology {name!r} does not run it")
    if name == "allreduce":
        return _topo_allreduce(grads, scheme, state, key, active,
                               mode=sync_mode, codec=codec,
                               use_pallas=use_pallas, want_own=want_own,
                               fault=fault, fault_step=fault_step)
    if name == "param_server":
        if not scheme.quantized:
            return _topo_allreduce(grads, scheme, state, key, active,
                                   mode="fp32", codec=codec,
                                   use_pallas=use_pallas,
                                   want_own=want_own)
        return _topo_param_server(grads, scheme, state, key, active,
                                  server_bits=server_bits, codec=codec,
                                  use_pallas=use_pallas,
                                  want_own=want_own)
    if name == "ring":
        return _topo_ring(grads, scheme, state, key, active, codec=codec,
                          use_pallas=use_pallas, want_own=want_own)
    raise ValueError(f"unknown topology {name!r}; known: {TOPOLOGIES}")


def run_compressed(
    name: str,
    grads: jnp.ndarray,
    scheme: QuantScheme,
    state: SchemeState,
    algorithm,
    comp_state,
    key: jax.Array,
    *,
    active: jnp.ndarray | None = None,
    sync_mode: str = "all_gather",
    server_bits: int | None = sync.TWO_PHASE_BITS,
    use_pallas: bool = False,
    fault: FaultModel | None = None,
    fault_step=0,
):
    """``run_topology`` under a ``repro.compress`` algorithm.

    ``comp_state`` is the M-stacked per-worker ``CompressState``
    (leading worker axis on every leaf).  Sequences the same
    prepare -> wire -> feedback hook as ``dist.sync
    .compressed_allreduce``, with per-worker residuals: worker w's
    residual is derived from ITS own round trip only.  With the
    stateless ``plain`` algorithm the wire path (and therefore the
    aggregate) is bit-identical to ``run_topology`` on the same codec.

    Returns ``(TopologyResult, new comp_state)``.
    """
    grads = jnp.asarray(grads)
    prep = jax.vmap(algorithm.prepare)(grads, comp_state)
    codec = algorithm.codec if scheme.quantized else None
    res = run_topology(name, prep, scheme, state, key, active=active,
                       sync_mode=sync_mode, server_bits=server_bits,
                       codec=codec, use_pallas=use_pallas,
                       want_own=algorithm.stateful,
                       fault=fault, fault_step=fault_step)
    own = res.own if algorithm.stateful else prep
    new_comp = jax.vmap(algorithm.feedback)(comp_state, prep, own)
    return res, new_comp

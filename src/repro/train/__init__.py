"""Training substrate: optimizer, state, step, data, checkpointing."""
from .optim import OptimConfig, OptState, apply_updates, init_opt_state, schedule
from .train_step import TrainConfig, TrainState, init_train_state, make_train_step
from .data import DataConfig, Pipeline
from . import checkpoint

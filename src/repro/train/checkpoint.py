"""Minimal pytree checkpointing (npz-backed; no orbax in this image).

Crash-safe by construction: ``save`` writes to a sibling tmp file,
fsyncs it, then ``os.replace``s into place — a reader never observes a
torn checkpoint, and a crash mid-save leaves the previous checkpoint
intact.  ``save_step`` / ``latest_checkpoint`` / ``restore_latest``
layer a step-numbered directory convention on top, which is what the
launcher's periodic-save + auto-resume loop (``repro.launch.train``)
uses to survive worker crashes.
"""
from __future__ import annotations

import os
import re
import time

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)\.npz$")


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def save(path: str, tree) -> None:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz has no bf16 cast
            arr = arr.astype(np.float32)
        flat[_keystr(kp)] = arr
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())  # durable before the rename commits it
    os.replace(tmp, path)


def save_with_retry(path: str, tree, *, attempts: int = 3,
                    backoff_s: float = 0.1) -> None:
    """``save`` with bounded retry/backoff on OSError (full disk, NFS
    hiccup, ...).  Re-raises the last error after ``attempts`` tries."""
    for i in range(attempts):
        try:
            save(path, tree)
            return
        except OSError:
            if i == attempts - 1:
                raise
            time.sleep(backoff_s * (2 ** i))


def restore(path: str, like):
    """Restore into the structure of `like` (shapes must match).

    Raises ValueError naming the exact missing/extra pytree keys on a
    structure mismatch, and the offending key on a shape mismatch —
    enough to diagnose a wrong --arch or optimizer without a debugger.
    """
    with np.load(path) as data:
        leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
        want = {_keystr(kp) for kp, _ in leaves_with_path}
        have = set(data.files)
        if want != have:
            missing = sorted(want - have)
            extra = sorted(have - want)
            raise ValueError(
                f"checkpoint {path!r} does not match the expected "
                f"structure: missing keys {missing or 'none'}, "
                f"extra keys {extra or 'none'} (saved with a different "
                "model/optimizer config?)")
        new_leaves = []
        for kp, leaf in leaves_with_path:
            arr = data[_keystr(kp)]
            if arr.shape != tuple(leaf.shape):
                raise ValueError(
                    f"checkpoint mismatch at {_keystr(kp)}: "
                    f"{arr.shape} vs {tuple(leaf.shape)}")
            new_leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


# ---------------------------------------------------------------------------
# step-numbered checkpoint directories (periodic save + auto-resume)
# ---------------------------------------------------------------------------

def step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}.npz")


def save_step(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Save ``tree`` as ``ckpt_dir/step_NNNNNNNN.npz`` (with retry),
    pruning all but the newest ``keep`` checkpoints.  Returns the path."""
    path = step_path(ckpt_dir, step)
    save_with_retry(path, tree)
    steps = sorted(list_checkpoints(ckpt_dir))
    for old in steps[:-keep] if keep > 0 else []:
        try:
            os.remove(step_path(ckpt_dir, old))
        except OSError:
            pass  # pruning is best-effort; never fail the save
    return path


def list_checkpoints(ckpt_dir: str) -> list[int]:
    """Step numbers of the checkpoints present in ``ckpt_dir``."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_checkpoint(ckpt_dir: str) -> tuple[int, str] | None:
    """(step, path) of the newest checkpoint, or None if there is none."""
    steps = list_checkpoints(ckpt_dir)
    if not steps:
        return None
    return steps[-1], step_path(ckpt_dir, steps[-1])


def restore_latest(ckpt_dir: str, like) -> tuple[int, object] | None:
    """Restore the newest checkpoint in ``ckpt_dir`` into the structure
    of ``like``; returns (step, tree) or None when the directory holds
    no checkpoint (fresh start)."""
    found = latest_checkpoint(ckpt_dir)
    if found is None:
        return None
    step, path = found
    return step, restore(path, like)

"""Minimal pytree checkpointing (npz-backed; no orbax in this image)."""
from __future__ import annotations

import os

import jax
import numpy as np


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def save(path: str, tree) -> None:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz has no bf16 cast
            arr = arr.astype(np.float32)
        flat[_keystr(kp)] = arr
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)


def restore(path: str, like):
    """Restore into the structure of `like` (shapes must match)."""
    with np.load(path) as data:
        leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
        new_leaves = []
        for kp, leaf in leaves_with_path:
            arr = data[_keystr(kp)]
            if arr.shape != tuple(leaf.shape):
                raise ValueError(
                    f"checkpoint mismatch at {_keystr(kp)}: "
                    f"{arr.shape} vs {tuple(leaf.shape)}")
            new_leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)

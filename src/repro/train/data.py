"""Data pipeline.

Two sources, both deterministic and host-side (the container is offline):

  * ``markov``: sequences sampled from a fixed random bigram table — a
    *learnable* synthetic LM task, so integration tests and examples can
    assert the loss actually decreases;
  * ``uniform``: i.i.d. uniform tokens (throughput/dry-run filler).

Batches are yielded as already-global arrays; the launcher shards them
over the DP mesh axes with ``jax.device_put`` + NamedSharding.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    kind: str = "markov"        # markov | uniform
    vocab_size: int = 256
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    markov_temperature: float = 0.5


class Pipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        if cfg.kind == "markov":
            logits = rng.standard_normal((cfg.vocab_size, cfg.vocab_size))
            logits /= cfg.markov_temperature
            p = np.exp(logits - logits.max(-1, keepdims=True))
            self.table = (p / p.sum(-1, keepdims=True)).astype(np.float64)
        else:
            self.table = None

    def batch(self, step: int):
        """Returns dict(ids (B,S) int32, labels (B,S) int32)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, 0xD1CE]))
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        if cfg.kind == "uniform":
            toks = rng.integers(0, V, size=(B, S + 1), dtype=np.int32)
        else:
            toks = np.empty((B, S + 1), np.int32)
            toks[:, 0] = rng.integers(0, V, size=B)
            u = rng.random((B, S))
            cdf = np.cumsum(self.table, axis=-1)
            for t in range(S):
                toks[:, t + 1] = np.argmax(
                    u[:, t, None] < cdf[toks[:, t]], axis=-1)
        return {
            "ids": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }

    def vision_stub(self, num_tokens: int, d_model: int, step: int):
        """Precomputed patch/frame embeddings (the modality-frontend stub
        allowed by the assignment for [vlm]/[audio] archs)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, 0xFACE]))
        x = rng.standard_normal(
            (cfg.global_batch, num_tokens, d_model)).astype(np.float32)
        return jnp.asarray(x)

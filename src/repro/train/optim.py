"""Optimizers and LR schedules.

The paper trains with SGD + momentum 0.9, weight decay 1e-4, step-decay
LR (x0.1 at milestones) — Table 3.  We implement UMSGD (App. I, Eq. 45),
whose l=0 / l=1 special cases are heavy-ball and Nesterov, plus AdamW for
the transformer configs, all as pure pytree transforms (no optax
dependency in this offline image).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    name: str = "sgdm"          # sgdm | adamw
    lr: float = 0.1
    momentum: float = 0.9
    nesterov: bool = False      # UMSGD l=1 vs l=0
    weight_decay: float = 1e-4
    # schedule
    warmup_steps: int = 0
    decay_milestones: tuple = ()   # steps at which lr *= decay_factor
    decay_factor: float = 0.1
    # adamw
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8


class OptState(NamedTuple):
    mu: dict            # momentum / first moment
    nu: dict | None     # second moment (adamw) or None-like zeros
    count: jnp.ndarray


def init_opt_state(cfg: OptimConfig, params) -> OptState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    nu = jax.tree.map(jnp.zeros_like, params) if cfg.name == "adamw" else None
    return OptState(mu=zeros, nu=nu, count=jnp.zeros((), jnp.int32))


def schedule(cfg: OptimConfig, step) -> jnp.ndarray:
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.warmup_steps > 0:
        lr = lr * jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    for m in cfg.decay_milestones:
        lr = jnp.where(step >= m, lr * cfg.decay_factor, lr)
    return lr


def apply_updates(cfg: OptimConfig, params, grads, state: OptState):
    """Returns (new_params, new_state)."""
    step = state.count
    lr = schedule(cfg, step)

    if cfg.name == "sgdm":
        def upd(p, g, m):
            g = g + cfg.weight_decay * p
            m_new = cfg.momentum * m + g
            if cfg.nesterov:
                step_dir = g + cfg.momentum * m_new
            else:
                step_dir = m_new
            return (p - lr * step_dir).astype(p.dtype), m_new

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        return new_p, OptState(mu=new_m, nu=state.nu, count=step + 1)

    if cfg.name == "adamw":
        t = (step + 1).astype(jnp.float32)
        c1 = 1.0 - cfg.b1 ** t
        c2 = 1.0 - cfg.b2 ** t

        def upd(p, g, m, v):
            m_new = cfg.b1 * m + (1 - cfg.b1) * g
            v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
            mhat = m_new / c1
            vhat = v_new / c2
            new_p = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                              + cfg.weight_decay * p)
            return new_p.astype(p.dtype), m_new, v_new

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(*a) for a in zip(flat_p, flat_g, flat_m, flat_v)]
        return (
            treedef.unflatten([o[0] for o in out]),
            OptState(mu=treedef.unflatten([o[1] for o in out]),
                     nu=treedef.unflatten([o[2] for o in out]),
                     count=step + 1),
        )

    raise ValueError(cfg.name)

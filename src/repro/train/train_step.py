"""Adaptive quantized data-parallel train step (Algorithm 1, end to end).

Per step, *inside one shard_map / jit*:
  1. local gradient from the device's batch shard (jax.grad inside
     shard_map -> genuinely local, no implicit psum over the data axes);
  2. on the paper's sparse schedule: fit bucket statistics (Pallas
     kernel), merge sufficient statistics across workers (tiny
     all_gather), run the ALQ/AMQ level update (lines 2-4);
  3. ENCODE -> collective -> DECODE -> average (lines 6-9) via
     dist.sync.quantized_allreduce in the configured wire mode;
  4. SGD-momentum / AdamW update (replicated across DP by construction
     since every worker decodes the same aggregate).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.compress import make_algorithm
from repro.core.codec import make_codec
from repro.core.schemes import QuantScheme, SchemeState
from repro.dist.sync import (
    compressed_allreduce, maybe_update_levels, quantized_allreduce)
from repro.models.transformer import Model
from .optim import OptimConfig, OptState, apply_updates, init_opt_state


class SyncMetricsLite(NamedTuple):
    """Wire metrics surfaced in real training logs — the same
    per-direction split + entropy + compression accounting ``repro.sim``
    reports.  Defaulted fields are float32 scalars (not Python floats)
    so ``metric_specs()`` harnesses see one metric dtype on every
    path."""

    comm_bits_per_coord: jnp.ndarray
    quant_error: jnp.ndarray
    reduce_bits_per_coord: jnp.ndarray
    broadcast_bits_per_coord: jnp.ndarray
    entropy_bits_per_coord: jnp.ndarray
    residual_norm: jnp.ndarray = jnp.float32(0.0)
    kept_fraction: jnp.ndarray = jnp.float32(1.0)
    # wire-integrity accounting (dist.sync with ``integrity=`` plans):
    # fraction of (worker, bucket) payload slots excluded as corrupt,
    # and workers whose whole payload was excluded
    corrupt_fraction: jnp.ndarray = jnp.float32(0.0)
    excluded_workers: jnp.ndarray = jnp.float32(0.0)


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    scheme_state: SchemeState
    step: jnp.ndarray
    rng: jax.Array
    # repro.compress algorithm state (error-feedback residual + step
    # counter), checkpointed/restored like optimizer state.  ``None``
    # for stateless algorithms (the default 'plain'), keeping the state
    # pytree — and every existing checkpoint/spec construction —
    # unchanged unless a stateful algorithm is configured.  The residual
    # is PER-WORKER state: it carries a leading data-parallel axis
    # (dp, d), sharded over the data axes (``compress_state_specs``), so
    # each rank owns exactly its residual row.
    compress_state: Any = None


def compress_state_specs(state: TrainState, data_axes=("data",)):
    """shard_map specs for ``TrainState.compress_state``: the residual
    is sharded over the data axes (one row per DP rank), the step
    counter replicated.  ``None`` passes through for stateless
    algorithms."""
    from jax.sharding import PartitionSpec as P
    if state.compress_state is None:
        return None
    from repro.compress import CompressState
    return CompressState(residual=P(tuple(data_axes)), step=P())


# every scalar train_step emits; launch/dryrun/test harnesses build their
# shard_map out_specs from this instead of hard-coding the key set
TRAIN_METRIC_KEYS = (
    "loss", "grad_norm", "comm_bits_per_coord", "quant_error",
    "reduce_bits_per_coord", "broadcast_bits_per_coord",
    "entropy_bits_per_coord", "residual_norm", "kept_fraction",
    "corrupt_fraction", "excluded_workers",
)


def metric_specs():
    """Replicated shard_map out_specs for the train-step metrics dict."""
    from jax.sharding import PartitionSpec as P
    return {k: P() for k in TRAIN_METRIC_KEYS}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    scheme: QuantScheme = QuantScheme()
    optim: OptimConfig = OptimConfig()
    sync_mode: str = "all_gather"       # fp32 | all_gather | two_phase
    update_milestones: tuple = (100, 2000)
    update_every: int = 10_000          # additionally every k steps
    use_pallas: bool = True
    microbatches: int = 1               # grad accumulation (activation mem)
    # wire codec of the DP allreduce path ('uniform' | 'mixed_width' |
    # 'entropy[:base]' — the entropy-coded payload family with the
    # cold-start canonical-Huffman table; comm_bits_per_coord then
    # reports the MEASURED coded volume).  FSDP models configure their
    # backward wire separately via ``Model(fsdp_codec=...)`` — train
    # metrics report whichever codec actually ships.
    codec: str = "uniform"
    # static per-bucket scheme-bits pattern for codec='mixed_width'
    # (tiled over the gradient's buckets; e.g. assign_mixed_widths
    # output).  Empty = the budget-neutral (bits-1, bits+1) cycle.
    mixed_width_pattern: tuple = ()
    # compression algorithm around the codec (repro.compress):
    # 'plain' | 'ef[:warmup_steps]' | 'topk[:k]'.  Drives the DP
    # allreduce path; for FSDP backward error feedback see
    # ``dist.fsdp.make_gather(algorithm=...)``.
    compress: str = "plain"
    # opt-in wire integrity: per-bucket checksum words in the payload;
    # dist.sync excludes detected-corrupt buckets from the aggregate
    # and reports corrupt_fraction / excluded_workers in the metrics
    integrity: bool = False


def _make_algo(tcfg: TrainConfig):
    if not tcfg.scheme.quantized:
        return None
    # None = the scheme's uniform codec; only a non-default codec (or
    # an integrity-on plan) is passed explicitly (make_algorithm rejects
    # codec overrides for 'topk', which owns its SparseCodec)
    codec = None
    if tcfg.codec != "uniform" or tcfg.integrity:
        codec = make_codec(tcfg.scheme, tcfg.codec,
                           tcfg.mixed_width_pattern,
                           integrity=tcfg.integrity)
    return make_algorithm(tcfg.compress, tcfg.scheme, codec=codec)


def init_train_state(model: Model, tcfg: TrainConfig, key) -> TrainState:
    params = model.init(key)
    algo = _make_algo(tcfg)
    compress_state = None
    if algo is not None and algo.stateful:
        if model.param_mode == "fsdp":
            raise NotImplementedError(
                "stateful compression on the FSDP path is wired at the "
                "gather level (dist.fsdp.make_gather(algorithm=...)), "
                "not through TrainConfig.compress")
        d = sum(int(x.size) for x in jax.tree.leaves(params))
        cs = algo.init_state(d)
        # one residual row per DP rank (sharded over the data axes)
        compress_state = cs._replace(
            residual=jnp.zeros((model.dp, d), jnp.float32))
    return TrainState(
        params=params,
        opt=init_opt_state(tcfg.optim, params),
        scheme_state=tcfg.scheme.init_state(),
        step=jnp.zeros((), jnp.int32),
        rng=jax.random.PRNGKey(0),
        compress_state=compress_state,
    )


def _is_update_step(tcfg: TrainConfig, step):
    hit = jnp.zeros((), bool)
    for m in tcfg.update_milestones:
        hit |= step == m
    if tcfg.update_every > 0:
        hit |= (step > 0) & (step % tcfg.update_every == 0)
    return hit


def make_train_step(model: Model, tcfg: TrainConfig, *, data_axes=("data",)):
    """Returns train_step(state, batch) for use INSIDE shard_map."""
    scheme = tcfg.scheme
    algo = _make_algo(tcfg)
    codec = algo.codec if algo is not None else None

    def train_step(state: TrainState, batch):
        fsdp = model.param_mode == "fsdp"
        # worker-distinct randomness over the DP axes only (so grads of
        # TP-replicated params stay bit-identical across the model axis)
        data_rank0 = jnp.zeros((), jnp.int32)
        for ax in data_axes:
            data_rank0 = (data_rank0 * jax.lax.axis_size(ax)
                          + jax.lax.axis_index(ax))
        base_key = jax.random.fold_in(
            jax.random.fold_in(state.rng, state.step), data_rank0)
        sync_ctx = (state.scheme_state.levels, base_key) if fsdp else None

        k = tcfg.microbatches
        if k <= 1:
            def loss_fn(p):
                return model.loss(p, batch, sync_ctx)

            loss, grads = jax.value_and_grad(loss_fn)(state.params)
        else:
            # gradient accumulation over k micro-batches (scan keeps the
            # live activation set to one micro-batch)
            micro = jax.tree.map(
                lambda a: a.reshape((k, a.shape[0] // k) + a.shape[1:]),
                batch)

            def micro_step(carry, mb):
                loss_acc, gacc = carry
                l, g = jax.value_and_grad(
                    lambda p: model.loss(p, mb, sync_ctx))(state.params)
                gacc = jax.tree.map(lambda a, b: a + b, gacc, g)
                return (loss_acc + l, gacc), None

            # accumulate in the parameter dtype (f32 for f32 masters;
            # bf16 for bf16-param configs like jamba — their grads are
            # quantized on the wire anyway)
            zeros = jax.tree.map(
                lambda a: jnp.zeros(a.shape, a.dtype), state.params)
            (loss, grads), _ = jax.lax.scan(
                micro_step, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / k
            grads = jax.tree.map(lambda a: a / k, grads)

        new_comp = state.compress_state
        if fsdp:
            # gradients were already quantized-reduce-scattered inside the
            # FSDP gather's custom_vjp; levels adapt from one (flat,
            # already-sharded) slot's gradient — no full ravel copy.
            stats_src = grads["slots"][0].reshape(-1)
            scheme_state = maybe_update_levels(
                stats_src, scheme, state.scheme_state,
                _is_update_step(tcfg, state.step),
                axes=data_axes, use_pallas=tcfg.use_pallas)
            # per-direction wire cost of the backward reduce-scatter.
            # FSDP's wire codec is baked into the Model's gather
            # (``fsdp_codec``), NOT TrainConfig.codec (which drives the
            # DP allreduce path) — report what actually ships.
            fsdp_codec = getattr(model, "_fsdp_codec", codec)
            quantized_rs = scheme.quantized and fsdp_codec is not None
            wire = (fsdp_codec.nominal_bits_per_coord if quantized_rs
                    else 32.0)
            # flat slot/embed leaves were synced in the gather's vjp; the
            # small replicated leaves (final_norm) still need the DP mean
            M = 1
            for ax in data_axes:
                M *= jax.lax.axis_size(ax)
            grads_synced = dict(grads)
            grads_synced["final_norm"] = jax.lax.psum(
                grads["final_norm"], tuple(data_axes)) / M
            gn_sq = sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in jax.tree.leaves(grads))
            grad_norm = jnp.sqrt(gn_sq)
            metrics = SyncMetricsLite(
                comm_bits_per_coord=jnp.float32(
                    2.0 * wire if quantized_rs else 32.0),
                quant_error=jnp.float32(0.0),
                reduce_bits_per_coord=jnp.float32(wire),
                broadcast_bits_per_coord=jnp.float32(
                    wire if quantized_rs else 0.0),
                entropy_bits_per_coord=jnp.asarray(
                    scheme_state.entropy_bits, jnp.float32))
        else:
            flat, unravel = ravel_pytree(grads)
            scheme_state = maybe_update_levels(
                flat, scheme, state.scheme_state,
                _is_update_step(tcfg, state.step),
                axes=data_axes, use_pallas=tcfg.use_pallas)
            if algo is None:  # fp32 / super_sgd: plain mean psum
                synced, metrics = quantized_allreduce(
                    flat, scheme, scheme_state, base_key,
                    axes=data_axes, mode=tcfg.sync_mode,
                    use_pallas=tcfg.use_pallas)
            else:
                cs = state.compress_state
                if cs is not None:
                    # inside shard_map each rank holds its (1, d) row of
                    # the data-axis-sharded residual
                    cs = cs._replace(residual=cs.residual[0])
                synced, new_comp, metrics = compressed_allreduce(
                    flat, scheme, scheme_state, algo, cs, base_key,
                    axes=data_axes, mode=tcfg.sync_mode,
                    use_pallas=tcfg.use_pallas)
                if new_comp is not None:
                    new_comp = new_comp._replace(
                        residual=new_comp.residual[None])
                    # per-rank residual magnitudes differ; report the
                    # replicated DP mean
                    metrics = metrics._replace(
                        residual_norm=jax.lax.pmean(
                            jnp.asarray(metrics.residual_norm,
                                        jnp.float32),
                            tuple(data_axes)))
            grads_synced = unravel(synced)
            grad_norm = jnp.sqrt(jnp.sum(synced * synced))

        new_params, new_opt = apply_updates(
            tcfg.optim, state.params, grads_synced, state.opt)

        new_state = TrainState(
            params=new_params, opt=new_opt, scheme_state=scheme_state,
            step=state.step + 1, rng=state.rng,
            compress_state=new_comp)
        out_metrics = {
            "loss": jax.lax.pmean(loss, tuple(data_axes)),
            "grad_norm": grad_norm,
            "comm_bits_per_coord": metrics.comm_bits_per_coord,
            "quant_error": metrics.quant_error,
            "reduce_bits_per_coord": metrics.reduce_bits_per_coord,
            "broadcast_bits_per_coord": metrics.broadcast_bits_per_coord,
            "entropy_bits_per_coord": metrics.entropy_bits_per_coord,
            "residual_norm": jnp.asarray(metrics.residual_norm,
                                         jnp.float32),
            "kept_fraction": jnp.asarray(metrics.kept_fraction,
                                         jnp.float32),
            "corrupt_fraction": jnp.asarray(metrics.corrupt_fraction,
                                            jnp.float32),
            "excluded_workers": jnp.asarray(metrics.excluded_workers,
                                            jnp.float32),
        }
        return new_state, out_metrics

    return train_step

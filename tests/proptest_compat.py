"""Drop-in fallback for the hypothesis subset the test-suite uses.

The offline CI image does not ship hypothesis; these shims keep the
property tests running there as deterministic seeded random sweeps
(``max_examples`` draws per test).  When real hypothesis is installed the
test modules import it instead and get shrinking/replay for free.
"""
from __future__ import annotations

import random
import zlib


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def draw(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: rng.choice(seq))


def settings(max_examples: int = 25, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_max_examples", 25)
            # stable per-test seed so failures reproduce across runs
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                draws = {k: s.draw(rng) for k, s in strats.items()}
                fn(**draws)

        # NOT functools.wraps: pytest must see the zero-arg signature,
        # not the strategy parameters (it would demand fixtures for them)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco

"""Level adaptation: ALQ coordinate descent satisfies Thm 1's fixed point
and monotonically decreases Psi; the projection-free GD (Eq. 7) stays
feasible; AMQ's closed-form derivative matches finite differences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    TruncNormStats,
    alq_gd_update,
    alq_update,
    amq_gradient,
    amq_objective,
    amq_update,
    expected_variance,
    exp_levels,
    is_feasible,
    mixture_cdf,
    partial_moment0,
    partial_moment1,
    psi_gradient,
    uniform_levels,
)
from repro.core.schemes import QuantScheme


def stats_example(n=3, seed=0):
    rng = np.random.default_rng(seed)
    mu = rng.uniform(0.02, 0.4, n).astype(np.float32)
    sig = rng.uniform(0.02, 0.3, n).astype(np.float32)
    g = rng.uniform(0.1, 1.0, n).astype(np.float32)
    return TruncNormStats(jnp.asarray(mu), jnp.asarray(sig),
                          jnp.asarray(g / g.sum()))


@pytest.mark.parametrize("init", [uniform_levels, lambda b: exp_levels(b, 0.5)])
@pytest.mark.parametrize("bits", [2, 3, 4])
def test_alq_decreases_psi_and_feasible(init, bits):
    stats = stats_example()
    lv0 = init(bits)
    psi0 = float(expected_variance(stats, lv0))
    lv = alq_update(lv0, stats, sweeps=20)
    psi1 = float(expected_variance(stats, lv))
    assert psi1 <= psi0 * 1.0001
    assert bool(is_feasible(lv))
    # converged: further sweeps barely move levels (CD needs more sweeps
    # at higher bit widths; tolerance scales with the level count)
    lv2 = alq_update(lv, stats, sweeps=2)
    assert float(jnp.abs(lv2 - lv).max()) < 2e-3 * lv.shape[0]


def test_alq_fixed_point_satisfies_theorem1():
    """At convergence, each level satisfies Eq. (4):
    F(l_j) = F(l_{j+1}) - int (r - l_{j-1})/(l_{j+1} - l_{j-1}) dF."""
    stats = stats_example(seed=1)
    lv = alq_update(uniform_levels(3), stats, sweeps=25)
    for j in range(1, lv.shape[0] - 1):
        a, b, c = lv[j - 1], lv[j], lv[j + 1]
        m1 = partial_moment1(stats, a, c)
        m0 = partial_moment0(stats, a, c)
        rhs = mixture_cdf(stats, c) - (m1 - a * m0) / (c - a)
        lhs = mixture_cdf(stats, b)
        np.testing.assert_allclose(float(lhs), float(rhs), atol=2e-3)


def test_psi_gradient_matches_finite_difference():
    stats = stats_example(seed=2)
    lv = uniform_levels(3)
    g = psi_gradient(lv, stats)
    eps = 1e-4
    for j in range(1, lv.shape[0] - 1):
        up = lv.at[j].add(eps)
        dn = lv.at[j].add(-eps)
        fd = (expected_variance(stats, up)
              - expected_variance(stats, dn)) / (2 * eps)
        np.testing.assert_allclose(float(g[j - 1]), float(fd), atol=2e-3,
                                   rtol=0.05)


def test_gd_projection_free_feasible_and_decreases():
    stats = stats_example(seed=3)
    lv0 = uniform_levels(4)
    lv = alq_gd_update(lv0, stats, steps=100)
    assert bool(is_feasible(lv))
    assert float(expected_variance(stats, lv)) < float(
        expected_variance(stats, lv0))


def test_amq_gradient_matches_fd_and_update_improves():
    stats = stats_example(seed=4)
    for bits in (2, 3, 4):
        p = jnp.float32(0.55)
        g = float(amq_gradient(p, stats, bits))
        eps = 1e-3
        fd = float(
            (amq_objective(p + eps, stats, bits)
             - amq_objective(p - eps, stats, bits)) / (2 * eps))
        np.testing.assert_allclose(g, fd, rtol=0.05, atol=1e-4)

    p_new = amq_update(jnp.float32(0.5), stats, bits=3, steps=200)
    assert float(amq_objective(p_new, stats, 3)) <= float(
        amq_objective(jnp.float32(0.5), stats, 3)) + 1e-9


def test_scheme_registry_updates():
    stats = stats_example(seed=5)
    for name in ("alq", "alq_n", "alq_gd", "amq", "amq_n",
                 "alq_inf", "amq_inf"):
        sch = QuantScheme(name=name, bits=3)
        st0 = sch.init_state()
        st1 = sch.update_state(st0, stats)
        assert int(st1.num_updates) == 1
        assert bool(is_feasible(st1.levels))
        psi0 = float(expected_variance(stats, st0.levels))
        psi1 = float(expected_variance(stats, st1.levels))
        assert psi1 <= psi0 * 1.01, name
    for name in ("qsgdinf", "nuqsgd", "trn", "fp32"):
        sch = QuantScheme(name=name)
        st0 = sch.init_state()
        st1 = sch.update_state(st0, stats)
        assert np.array_equal(np.asarray(st0.levels), np.asarray(st1.levels))

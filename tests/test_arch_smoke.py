"""Per-architecture smoke tests (assignment deliverable f): a REDUCED
variant of each assigned family (<=2-8 layers, d_model<=512, <=4 experts)
runs one forward + one quantized train step on CPU; output shapes are
checked and outputs are finite.  The FULL configs are exercised by the
dry-run only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core.schemes import QuantScheme
from repro.models import Model
from repro.train.optim import OptimConfig
from repro.train.train_step import (
    TrainConfig, TrainState, init_train_state, make_train_step)

ARCHS = list(configs.ARCH_NAMES)


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    assert cfg.num_layers <= 8 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.num_experts <= 4
    mesh = _mesh11()
    model = Model(cfg, tp=1, dp=1)
    B, S = 2, 32
    ids = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0,
                             cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    batch = {"ids": ids, "labels": labels}
    vspec = None
    if cfg.cross_attn_every:
        batch["vision"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, 8, cfg.d_model), jnp.float32)
        vspec = P("data")

    tcfg = TrainConfig(
        scheme=QuantScheme(name="alq", bits=3, bucket_size=512),
        optim=OptimConfig(name="sgdm", lr=0.05),
        sync_mode="all_gather",
        update_milestones=(0,), update_every=0)
    step = make_train_step(model, tcfg, data_axes=("data",))

    pspecs = model.param_specs()
    bspecs = {k: (P("data") if k != "vision" else vspec) for k in batch}
    state_specs = None

    with jax.set_mesh(mesh):
        state = init_train_state(model, tcfg, jax.random.PRNGKey(3))
        state_specs = TrainState(
            params=pspecs,
            opt=type(state.opt)(mu=pspecs, nu=None, count=P()),
            scheme_state=jax.tree.map(lambda _: P(), state.scheme_state),
            step=P(), rng=P())
        fwd = jax.jit(jax.shard_map(
            lambda p, i, v: model.forward(p, i, v),
            in_specs=(pspecs, P("data"), vspec),
            out_specs=(P("data"), P()), check_vma=False))
        x, aux = fwd(state.params, ids, batch.get("vision"))
        assert x.shape == (B, S, cfg.d_model)
        assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))

        from repro.train.train_step import metric_specs
        train = jax.jit(jax.shard_map(
            step, in_specs=(state_specs, bspecs),
            out_specs=(state_specs, metric_specs()),
            check_vma=False))
        new_state, metrics = train(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["loss"]) > 0
        assert int(new_state.step) == 1
        # params actually moved
        delta = sum(
            float(jnp.abs(a.astype(jnp.float32)
                          - b.astype(jnp.float32)).max())
            for a, b in zip(jax.tree.leaves(state.params),
                            jax.tree.leaves(new_state.params)))
        assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_metadata(arch):
    """The FULL configs carry the exact assigned dimensions + citation."""
    cfg = configs.get_config(arch)
    assert cfg.source, arch
    assert cfg.param_count() > 0
    assert cfg.num_layers % cfg.group_size == 0

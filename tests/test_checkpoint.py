"""train.checkpoint: atomic saves, actionable restore errors, and the
step-numbered save/auto-resume convention the launcher's crash-recovery
loop is built on."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint


def _tree():
    return {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.zeros((3,), jnp.float32)}


def test_save_restore_roundtrip(tmp_path):
    path = str(tmp_path / "ck.npz")
    tree = _tree()
    checkpoint.save(path, tree)
    assert not os.path.exists(path + ".tmp")  # tmp committed atomically
    back = checkpoint.restore(path, tree)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))


def test_restore_names_missing_and_extra_keys(tmp_path):
    path = str(tmp_path / "ck.npz")
    checkpoint.save(path, {"w": jnp.zeros((2,)), "old": jnp.zeros((2,))})
    with pytest.raises(ValueError) as e:
        checkpoint.restore(path, {"w": jnp.zeros((2,)),
                                  "new": jnp.zeros((2,))})
    msg = str(e.value)
    assert "'new'" in msg and "'old'" in msg
    assert "missing" in msg and "extra" in msg


def test_restore_names_shape_mismatch_key(tmp_path):
    path = str(tmp_path / "ck.npz")
    checkpoint.save(path, {"w": jnp.zeros((2, 3))})
    with pytest.raises(ValueError, match=r"\['w'\]"):
        checkpoint.restore(path, {"w": jnp.zeros((3, 2))})


def test_save_step_resume_and_pruning(tmp_path):
    ckdir = str(tmp_path / "run")
    assert checkpoint.restore_latest(ckdir, _tree()) is None  # fresh
    for step in (1, 3, 5, 7):
        checkpoint.save_step(ckdir, step, {"s": jnp.float32(step)},
                             keep=2)
    assert checkpoint.list_checkpoints(ckdir) == [5, 7]  # pruned
    step, tree = checkpoint.restore_latest(ckdir, {"s": jnp.float32(0)})
    assert step == 7
    assert float(tree["s"]) == 7.0


def test_save_with_retry_survives_transient_failure(tmp_path, monkeypatch):
    path = str(tmp_path / "ck.npz")
    real_replace = os.replace
    fails = {"n": 2}

    def flaky(src, dst):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("transient")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", flaky)
    checkpoint.save_with_retry(path, _tree(), attempts=3,
                               backoff_s=0.0)
    assert os.path.exists(path)


def test_save_with_retry_reraises_after_attempts(tmp_path, monkeypatch):
    monkeypatch.setattr(os, "replace",
                        lambda s, d: (_ for _ in ()).throw(OSError("dead")))
    with pytest.raises(OSError):
        checkpoint.save_with_retry(str(tmp_path / "ck.npz"), _tree(),
                                   attempts=2, backoff_s=0.0)

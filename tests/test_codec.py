"""GradientCodec coverage (docs/wire_format.md, "Codec layer").

* uniform round trip at every width 1..8 x {fp32, fp16} norms: the
  payload decodes to exactly Q(v), and the plan's word counts match the
  payload shapes;
* mixed-width round trip: decode(encode) equals a per-bucket reference
  that quantizes each bucket on its own resampled grid;
* constant-width MixedWidthCodec == UniformCodec values (the layouts
  differ, the math must not);
* sharded payloads: diagonal decode of one's own sharded payload equals
  the unsharded values; traced-shard decode (``lax.switch`` under a
  named vmap axis) agrees with the static per-shard decode;
* MixedWidthCodec end to end: ``quantized_allreduce`` (both wire modes,
  replicated output) and the FSDP backward reduce-scatter, with error
  decreasing in width;
* ``assign_mixed_widths`` puts more bits where norm^2-weighted expected
  variance is, at (or under) the mean-bits wire budget;
* ``resample_levels`` keeps endpoints/monotonicity and is identity at
  equal size.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codec import (
    MixedWidthCodec,
    UniformCodec,
    WirePayload,
    assign_mixed_widths,
    codec_for_scheme,
    make_codec,
    resample_levels,
)
from repro.core.levels import num_levels, uniform_levels
from repro.core.packing import wire_bits_for
from repro.core.schemes import QuantScheme
from repro.dist import fsdp, sync
from repro.kernels import ops

KEY = jax.random.PRNGKey(11)
BS = 64


def _grad(d, scale=0.01, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (d,)) * scale


# ---------------------------------------------------------------------------
# round trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", range(1, 9))
@pytest.mark.parametrize("norm_dtype", ["float32", "float16"])
def test_uniform_roundtrip_all_widths(bits, norm_dtype):
    codec = UniformCodec(num_levels=num_levels(bits), bucket_size=BS,
                         norm_type="l2", norm_dtype=norm_dtype)
    lv = uniform_levels(bits)
    flat = _grad(1000 + bits)  # ragged tail exercises padding
    plan = codec.plan(flat.shape[0])
    vb = codec.bucketize(flat, plan)
    pay = codec.encode(vb, lv, KEY, plan, use_pallas=False)
    assert pay.words.shape == (plan.code_words,)
    assert pay.norm_words.shape == (plan.norm_words,)

    vals = codec.decode(pay, lv, plan, use_pallas=False)
    # reference: same u draw, quantize, wire-rounded norms
    u = jax.random.uniform(KEY, vb.shape, jnp.float32)
    c, n = ops.quantize_op(vb, u, lv, norm_type="l2", use_pallas=False)
    if norm_dtype == "float16":
        n = n.astype(jnp.float16).astype(jnp.float32)
    ref = ops.dequantize_op(c, n, lv, use_pallas=False).reshape(-1)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(ref))


@pytest.mark.parametrize("widths", [(1, 3), (2, 4, 6), (2, 3, 4, 3),
                                    (8, 1), (5,)])
@pytest.mark.parametrize("norm_dtype", ["float32", "float16"])
def test_mixed_roundtrip_per_bucket_reference(widths, norm_dtype):
    codec = MixedWidthCodec(bucket_size=BS, norm_type="l2",
                            norm_dtype=norm_dtype, widths=widths)
    lv = uniform_levels(3)
    flat = _grad(16 * BS)
    plan = codec.plan(flat.shape[0])
    vb = codec.bucketize(flat, plan)
    pay = codec.encode(vb, lv, KEY, plan, use_pallas=False)
    assert pay.words.shape == (plan.code_words,)
    vals = np.asarray(codec.decode(pay, lv, plan,
                                   use_pallas=False)).reshape(plan.nb, BS)

    u = jax.random.uniform(KEY, vb.shape, jnp.float32)
    w = np.asarray(plan.widths)
    ref = np.zeros((plan.nb, BS), np.float32)
    for b in sorted(set(w.tolist())):
        idx = np.nonzero(w == b)[0]
        lvb = resample_levels(lv, num_levels(int(b)))
        c, n = ops.quantize_op(vb[idx], u[idx], lvb, norm_type="l2",
                               use_pallas=False)
        if norm_dtype == "float16":
            n = n.astype(jnp.float16).astype(jnp.float32)
        ref[idx] = np.asarray(
            ops.dequantize_op(c, n, lvb, use_pallas=False))
    np.testing.assert_array_equal(vals, ref)


def test_constant_width_mixed_equals_uniform_values():
    """Same grid, different layout machinery -> same decoded values."""
    scheme = QuantScheme(name="alq", bits=3, bucket_size=BS)
    lv = scheme.init_state().levels
    uc = codec_for_scheme(scheme)
    mc = MixedWidthCodec(bucket_size=BS, norm_type="l2", widths=(3,))
    flat = _grad(20 * BS)
    pu, pm = uc.plan(flat.shape[0]), mc.plan(flat.shape[0])
    vu = uc.decode(uc.encode(uc.bucketize(flat, pu), lv, KEY, pu,
                             use_pallas=False), lv, pu, use_pallas=False)
    vm = mc.decode(mc.encode(mc.bucketize(flat, pm), lv, KEY, pm,
                             use_pallas=False), lv, pm, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(vu), np.asarray(vm))
    assert pu.bits_per_coord == pm.bits_per_coord


# ---------------------------------------------------------------------------
# sharded payloads
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", [
    UniformCodec(num_levels=8, bucket_size=BS, norm_type="l2"),
    MixedWidthCodec(bucket_size=BS, norm_type="l2", widths=(2, 4, 3)),
])
def test_sharded_diagonal_decode_matches_unsharded(codec):
    lv = uniform_levels(3)
    flat = _grad(32 * BS)
    M = 4
    plan = codec.plan(flat.shape[0], shards=M)
    vb = codec.bucketize(flat, plan)
    pay = codec.encode(vb, lv, KEY, plan, use_pallas=False)
    assert pay.words.shape == (M, plan.code_words)
    own = np.asarray(codec.decode(pay, lv, plan, shard=None,
                                  use_pallas=False)).reshape(-1)
    # static per-shard decode agrees with the diagonal
    for s in range(M):
        one = codec.decode(
            jax.tree.map(lambda a: a[s][None], pay), lv, plan, shard=s,
            use_pallas=False)
        np.testing.assert_array_equal(
            np.asarray(one)[0], own[s * plan.shard_n:(s + 1) * plan.shard_n])


def test_mixed_traced_shard_decode_under_vmap():
    """The lax.switch dispatch: each vmap lane decodes its own (static
    per-shard, different) layout from a traced rank."""
    mc = MixedWidthCodec(bucket_size=BS, norm_type="l2",
                         widths=(2, 5, 3, 4, 1, 6))
    lv = uniform_levels(3)
    flat = _grad(24 * BS)
    M = 4
    plan = mc.plan(flat.shape[0], shards=M)
    vb = mc.bucketize(flat, plan)
    pay = mc.encode(vb, lv, KEY, plan, use_pallas=False)
    ref = np.asarray(mc.decode(pay, lv, plan, shard=None,
                               use_pallas=False))

    def lane(w, nw):
        r = jax.lax.axis_index("w")
        out = mc.decode(WirePayload(w[None], nw[None]), lv, plan,
                        shard=r, use_pallas=False)
        return out[0]

    got = jax.vmap(lane, axis_name="w")(pay.words, pay.norm_words)
    np.testing.assert_array_equal(np.asarray(got), ref)


# ---------------------------------------------------------------------------
# end to end: allreduce + FSDP backward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["all_gather", "two_phase"])
def test_mixed_codec_quantized_allreduce(mode):
    M, D = 4, 6000
    scheme = QuantScheme(name="alq", bits=3, bucket_size=256)
    state = scheme.init_state()
    grads = jax.random.normal(jax.random.PRNGKey(0), (M, D)) * 0.01
    exact = np.asarray(grads).mean(0)

    def err_at(widths):
        codec = MixedWidthCodec(bucket_size=256, norm_type="l2",
                                widths=widths)

        def worker(g):
            return sync.quantized_allreduce(
                g, scheme, state, KEY, axes=("w",), mode=mode,
                use_pallas=False, codec=codec)

        out, m = jax.vmap(worker, axis_name="w")(grads)
        out = np.asarray(out)
        assert (out == out[0]).all()  # replicated in every mode
        assert np.isfinite(float(m.comm_bits_per_coord[0]))
        return ((out[0] - exact) ** 2).sum()

    coarse, fine = err_at((2, 4)), err_at((7, 8))
    assert np.isfinite(coarse) and fine < coarse / 10


def test_mixed_codec_fsdp_backward():
    M, Lp = 4, 8192
    scheme = QuantScheme(name="alq", bits=3, bucket_size=256)
    state = scheme.init_state()
    gf = jax.random.normal(jax.random.PRNGKey(3), (M, Lp)) * 0.01
    ref = np.asarray(gf).mean(0).reshape(M, -1)

    def rs_err(widths):
        codec = MixedWidthCodec(bucket_size=256, norm_type="l2",
                                widths=widths)
        rs = jax.vmap(
            lambda x: fsdp._quantized_reduce_scatter(
                x, state.levels, KEY, axes=("w",), codec=codec,
                use_pallas=False),
            axis_name="w")(gf)
        assert np.isfinite(np.asarray(rs)).all()
        return ((np.asarray(rs) - ref) ** 2).sum()

    assert rs_err((7, 8)) < rs_err((2, 4)) / 10


def test_make_gather_with_mixed_codec():
    """The full custom_vjp FSDP gather with a mixed-width codec, under
    real shard_map on fake devices (the custom_vjp backward composes
    with collective batching only under shard_map on this jax pin, so
    the harness matches tests/test_fsdp_quantized.py)."""
    import os
    import subprocess
    import sys

    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    body = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.codec import MixedWidthCodec
from repro.core.schemes import QuantScheme
from repro.dist import fsdp

M, Lp = 4, 4096
scheme = QuantScheme(name="alq", bits=8, bucket_size=256)
codec = MixedWidthCodec(bucket_size=256, norm_type="l2", widths=(7, 8))
gather = fsdp.make_gather(("w",), scheme, "quantized",
                          use_pallas=False, codec=codec)
lv = scheme.init_state().levels
key = jax.random.PRNGKey(11)
mesh = jax.make_mesh((4,), ("w",))
shards = jax.random.normal(jax.random.PRNGKey(5), (Lp,))
target = np.asarray(
    jax.random.normal(jax.random.PRNGKey(6), (Lp,))) * 0.01

def worker_loss(s, t):
    full = gather(s, lv, key)
    return jnp.sum((full - t) ** 2)

f = jax.jit(jax.shard_map(
    lambda s, t: jax.grad(worker_loss)(s, t), mesh=mesh,
    in_specs=(P("w"), P()), out_specs=P("w"), check_vma=False))
grads = np.asarray(f(shards, jnp.asarray(target)))
exact = 2.0 * (np.asarray(shards) - target)
rel = np.abs(grads - exact).max() / np.abs(exact).max()
assert rel < 0.05, rel  # ~8-bit RS noise, mean over M workers
print("MIXED_GATHER_OK", rel)
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = src
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"OUT:{proc.stdout}\nERR:{proc.stderr}"
    assert "MIXED_GATHER_OK" in proc.stdout


# ---------------------------------------------------------------------------
# width assignment + level resampling
# ---------------------------------------------------------------------------

def test_assignment_follows_norm_weighted_error():
    nb = 32
    mu = np.full(nb, 0.1)
    sig = np.full(nb, 0.05)
    norms = np.geomspace(0.01, 10.0, nb)
    wid = assign_mixed_widths(mu, sig, norms, uniform_levels(3),
                              mean_bits=3)
    assert len(wid) == nb
    # budget respected in WIRE bits
    budget = nb * wire_bits_for(num_levels(3))
    spent = sum(wire_bits_for(num_levels(b)) for b in wid)
    assert spent <= budget
    # monotone in bucket norm: the top-norm octile outranks the bottom
    assert np.mean(wid[-4:]) > np.mean(wid[:4])


def test_resample_levels_identity_endpoints_monotone():
    lv = jnp.asarray([0.0, 0.05, 0.2, 0.45, 0.6, 0.8, 0.9, 1.0])
    assert resample_levels(lv, 8) is lv
    for n in (2, 4, 16):
        out = np.asarray(resample_levels(lv, n))
        assert out.shape == (n,)
        assert out[0] == 0.0 and out[-1] == 1.0
        assert (np.diff(out) > 0).all()


@pytest.mark.parametrize("bits", [1, 2, 3, 7, 8])
def test_make_codec_default_mixed_pattern_is_budget_neutral(bits):
    """Including the range edges (1, 8), where the default cycle
    degenerates to the uniform width rather than overspending."""
    scheme = QuantScheme(name="alq", bits=bits, bucket_size=256)
    mc = make_codec(scheme, "mixed_width")
    uc = make_codec(scheme, "uniform")
    assert isinstance(mc, MixedWidthCodec)
    assert mc.nominal_bits_per_coord == pytest.approx(
        uc.nominal_bits_per_coord)
    with pytest.raises(ValueError):
        make_codec(scheme, "nope")

"""Entropy coding: level-occupancy probabilities (Prop. 6) integrate to 1
and match Monte Carlo; Huffman code is a valid optimal prefix code
(H <= E[len] <= H+1, Thm 5); Thm 3's bound dominates the empirical bits."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    TruncNormStats,
    code_length_bound,
    entropy_bits,
    expected_bits_per_coordinate,
    expected_huffman_bits,
    huffman_code_lengths,
    level_probabilities,
    normalized_magnitudes,
    stochastic_round,
    uniform_levels,
)


def stats_example():
    return TruncNormStats(
        mu=jnp.asarray([0.1, 0.3], jnp.float32),
        sigma=jnp.asarray([0.05, 0.2], jnp.float32),
        gamma=jnp.asarray([0.6, 0.4], jnp.float32),
    )


def test_level_probabilities_sum_to_one_and_match_mc():
    stats = stats_example()
    levels = uniform_levels(3)
    probs = np.asarray(level_probabilities(levels, stats))
    np.testing.assert_allclose(probs.sum(), 1.0, atol=1e-5)

    # Monte Carlo: draw r from the mixture, stochastically round
    rng = np.random.default_rng(0)
    import scipy.stats
    comps = rng.choice(2, size=400_000, p=np.asarray(stats.gamma))
    r = np.empty(comps.shape)
    for i, (mu, sig) in enumerate(zip(stats.mu, stats.sigma)):
        a, b = (0 - mu) / sig, (1 - mu) / sig
        m = comps == i
        r[m] = scipy.stats.truncnorm.rvs(a, b, loc=float(mu),
                                         scale=float(sig), size=m.sum(),
                                         random_state=rng)
    u = jnp.asarray(rng.random(r.shape), jnp.float32)
    idx = np.asarray(stochastic_round(jnp.asarray(r, jnp.float32), levels, u))
    mc = np.bincount(idx, minlength=len(levels)) / len(idx)
    np.testing.assert_allclose(probs, mc, atol=5e-3)


def test_huffman_is_valid_optimal_prefix_code():
    probs = np.asarray([0.5, 0.2, 0.15, 0.1, 0.05])
    lengths = huffman_code_lengths(probs)
    # Kraft inequality with equality for a complete code
    assert abs(sum(2.0 ** -l for l in lengths) - 1.0) < 1e-9
    H = float(entropy_bits(jnp.asarray(probs)))
    E = expected_huffman_bits(probs)
    assert H <= E + 1e-9 <= H + 1


def test_bits_per_coordinate_and_thm3_bound():
    stats = stats_example()
    levels = uniform_levels(3)
    bits = expected_bits_per_coordinate(levels, stats)
    assert 1.0 < bits < 5.0  # 8 levels + sign, entropy-coded
    d = 100_000
    bound = code_length_bound(levels, stats, d)
    # Thm 3 bound must dominate the empirical expectation
    assert bound >= bits * d


def test_adaptive_levels_cost_fewer_bits_than_uniform_on_peaky_dist():
    from repro.core import alq_update
    stats = TruncNormStats(
        mu=jnp.asarray([0.02], jnp.float32),
        sigma=jnp.asarray([0.02], jnp.float32),
        gamma=jnp.asarray([1.0], jnp.float32),
    )
    uni = uniform_levels(3)
    ada = alq_update(uni, stats, sweeps=10)
    # adaptive grid concentrates levels where the mass is -> higher
    # entropy of symbols (more informative) but *much* lower variance;
    # Fig. 6's qualitative shape:
    assert float(ada[1]) < float(uni[1])

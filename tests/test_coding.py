"""Entropy coding: level-occupancy probabilities (Prop. 6) integrate to 1
and match Monte Carlo; Huffman code is a valid optimal prefix code
(H <= E[len] <= H+1, Thm 5); Thm 3's bound dominates the empirical bits.

Plus the property suite backing the EntropyCodec wire table
(hypothesis, with the seeded-sweep fallback on the offline image):

* Kraft EQUALITY for Huffman lengths of random ``TruncNormStats``
  occupancies (a Huffman code is complete, not just prefix-free);
* H(L) <= E[len] <= H(L) + 1 over the same random stats;
* ``level_probabilities`` sums to 1 and is non-negative under
  degenerate stats (sigma -> 0, single-level grids);
* the canonical wire code (``canonical_code`` / ``entropy_table``) is
  prefix-free over the signed-symbol alphabet, and the signed expansion
  has entropy exactly H(L) + Pr(sym != 0).
"""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline image: seeded-random fallback
    from proptest_compat import given, settings
    from proptest_compat import strategies as st

from repro.core import (
    TruncNormStats,
    canonical_code,
    code_length_bound,
    entropy_bits,
    entropy_table,
    expected_bits_per_coordinate,
    expected_huffman_bits,
    huffman_code_lengths,
    level_probabilities,
    normalized_magnitudes,
    signed_symbol_probabilities,
    stochastic_round,
    uniform_levels,
)


def stats_example():
    return TruncNormStats(
        mu=jnp.asarray([0.1, 0.3], jnp.float32),
        sigma=jnp.asarray([0.05, 0.2], jnp.float32),
        gamma=jnp.asarray([0.6, 0.4], jnp.float32),
    )


def test_level_probabilities_sum_to_one_and_match_mc():
    stats = stats_example()
    levels = uniform_levels(3)
    probs = np.asarray(level_probabilities(levels, stats))
    np.testing.assert_allclose(probs.sum(), 1.0, atol=1e-5)

    # Monte Carlo: draw r from the mixture, stochastically round
    rng = np.random.default_rng(0)
    import scipy.stats
    comps = rng.choice(2, size=400_000, p=np.asarray(stats.gamma))
    r = np.empty(comps.shape)
    for i, (mu, sig) in enumerate(zip(stats.mu, stats.sigma)):
        a, b = (0 - mu) / sig, (1 - mu) / sig
        m = comps == i
        r[m] = scipy.stats.truncnorm.rvs(a, b, loc=float(mu),
                                         scale=float(sig), size=m.sum(),
                                         random_state=rng)
    u = jnp.asarray(rng.random(r.shape), jnp.float32)
    idx = np.asarray(stochastic_round(jnp.asarray(r, jnp.float32), levels, u))
    mc = np.bincount(idx, minlength=len(levels)) / len(idx)
    np.testing.assert_allclose(probs, mc, atol=5e-3)


def test_huffman_is_valid_optimal_prefix_code():
    probs = np.asarray([0.5, 0.2, 0.15, 0.1, 0.05])
    lengths = huffman_code_lengths(probs)
    # Kraft inequality with equality for a complete code
    assert abs(sum(2.0 ** -l for l in lengths) - 1.0) < 1e-9
    H = float(entropy_bits(jnp.asarray(probs)))
    E = expected_huffman_bits(probs)
    assert H <= E + 1e-9 <= H + 1


def test_bits_per_coordinate_and_thm3_bound():
    stats = stats_example()
    levels = uniform_levels(3)
    bits = expected_bits_per_coordinate(levels, stats)
    assert 1.0 < bits < 5.0  # 8 levels + sign, entropy-coded
    d = 100_000
    bound = code_length_bound(levels, stats, d)
    # Thm 3 bound must dominate the empirical expectation
    assert bound >= bits * d


# ---------------------------------------------------------------------------
# property suite: random TruncNormStats -> occupancies -> Huffman
# ---------------------------------------------------------------------------

def _random_stats(mu, sigma, mu2, sigma2, w):
    g = np.asarray([w, 1.0 - w], np.float32)
    return TruncNormStats(
        mu=jnp.asarray([mu, mu2], jnp.float32),
        sigma=jnp.asarray([sigma, sigma2], jnp.float32),
        gamma=jnp.asarray(g / g.sum(), jnp.float32),
    )


@settings(max_examples=30, deadline=None)
@given(bits=st.integers(1, 4),
       mu=st.floats(0.0, 0.9), sigma=st.floats(1e-3, 0.5),
       mu2=st.floats(0.0, 0.9), sigma2=st.floats(1e-3, 0.5),
       w=st.floats(0.05, 0.95))
def test_huffman_kraft_equality_random_stats(bits, mu, sigma, mu2,
                                             sigma2, w):
    """Huffman codes are COMPLETE: sum 2^-len == 1 exactly (Kraft with
    equality), for occupancies of arbitrary fitted mixtures."""
    probs = np.asarray(level_probabilities(
        uniform_levels(bits), _random_stats(mu, sigma, mu2, sigma2, w)))
    lengths = huffman_code_lengths(probs)
    kraft = sum(2.0 ** -int(l) for l in lengths)
    assert abs(kraft - 1.0) < 1e-9, (probs, lengths)


@settings(max_examples=30, deadline=None)
@given(bits=st.integers(1, 4),
       mu=st.floats(0.0, 0.9), sigma=st.floats(1e-3, 0.5),
       mu2=st.floats(0.0, 0.9), sigma2=st.floats(1e-3, 0.5),
       w=st.floats(0.05, 0.95))
def test_huffman_within_one_bit_of_entropy_random_stats(bits, mu, sigma,
                                                        mu2, sigma2, w):
    """Thm 5: H(L) <= E[len] <= H(L) + 1 for random fitted mixtures."""
    probs = np.asarray(level_probabilities(
        uniform_levels(bits), _random_stats(mu, sigma, mu2, sigma2, w)))
    H = float(entropy_bits(jnp.asarray(probs)))
    E = expected_huffman_bits(probs)
    assert H - 1e-6 <= E <= H + 1.0 + 1e-6, (H, E, probs)


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(1, 4), mu=st.floats(0.0, 1.0),
       sigma=st.floats(1e-12, 1e-6))
def test_level_probabilities_degenerate_sigma(bits, mu, sigma):
    """sigma -> 0 collapses all mass onto (at most) two adjacent
    levels; the closed form must stay a distribution: non-negative,
    summing to 1, no NaNs."""
    stats = TruncNormStats(
        mu=jnp.asarray([mu], jnp.float32),
        sigma=jnp.asarray([sigma], jnp.float32),
        gamma=jnp.asarray([1.0], jnp.float32),
    )
    probs = np.asarray(level_probabilities(uniform_levels(bits), stats))
    assert np.isfinite(probs).all(), probs
    assert (probs >= 0.0).all(), probs
    np.testing.assert_allclose(probs.sum(), 1.0, atol=1e-5)


def test_level_probabilities_single_level_edge():
    """A one-level grid has a deterministic symbol: Pr = (1,)."""
    stats = stats_example()
    probs = np.asarray(level_probabilities(
        jnp.asarray([0.0], jnp.float32), stats))
    np.testing.assert_allclose(probs, [1.0])
    assert float(entropy_bits(jnp.asarray(probs))) == 0.0
    assert list(huffman_code_lengths(probs)) == [1]


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(1, 4),
       mu=st.floats(0.0, 0.9), sigma=st.floats(1e-3, 0.5),
       mu2=st.floats(0.0, 0.9), sigma2=st.floats(1e-3, 0.5),
       w=st.floats(0.05, 0.95))
def test_wire_table_is_prefix_free(bits, mu, sigma, mu2, sigma2, w):
    """The canonical wire code over the signed alphabet: no codeword is
    a (LSB-first) prefix of another, and the table covers 2L-1
    symbols."""
    levels = uniform_levels(bits)
    probs = np.asarray(level_probabilities(
        levels, _random_stats(mu, sigma, mu2, sigma2, w)))
    lengths, codes = entropy_table(probs, levels.shape[0])
    S = 2 * levels.shape[0] - 1
    assert len(lengths) == len(codes) == S
    for i in range(S):
        for j in range(S):
            if i == j:
                continue
            if lengths[i] <= lengths[j]:
                mask = (1 << lengths[i]) - 1
                assert (codes[j] & mask) != codes[i], (i, j)


def test_signed_symbol_entropy_is_H_plus_sign_bits():
    """The joint signed alphabet's entropy equals the metered accounting
    H(L) + Pr(sym != 0) exactly (signs are uniform given magnitude)."""
    stats = stats_example()
    levels = uniform_levels(3)
    probs = np.asarray(level_probabilities(levels, stats))
    joint = signed_symbol_probabilities(probs)
    np.testing.assert_allclose(joint.sum(), 1.0, atol=1e-6)
    H = float(entropy_bits(jnp.asarray(probs)))
    Hj = float(entropy_bits(jnp.asarray(joint, jnp.float32)))
    np.testing.assert_allclose(Hj, H + (1.0 - probs[0]), rtol=1e-5)


def test_canonical_code_known_lengths():
    """Textbook canonical assignment, bit-reversed for the LSB-first
    wire: lengths (1, 2, 3, 3) -> MSB codes 0, 10, 110, 111."""
    codes = canonical_code([1, 2, 3, 3])
    # bit-reversed within length: 0 -> 0; 10 -> 01; 110 -> 011; 111 -> 111
    assert list(codes) == [0b0, 0b01, 0b011, 0b111]


def test_adaptive_levels_cost_fewer_bits_than_uniform_on_peaky_dist():
    from repro.core import alq_update
    stats = TruncNormStats(
        mu=jnp.asarray([0.02], jnp.float32),
        sigma=jnp.asarray([0.02], jnp.float32),
        gamma=jnp.asarray([1.0], jnp.float32),
    )
    uni = uniform_levels(3)
    ada = alq_update(uni, stats, sweeps=10)
    # adaptive grid concentrates levels where the mass is -> higher
    # entropy of symbols (more informative) but *much* lower variance;
    # Fig. 6's qualitative shape:
    assert float(ada[1]) < float(uni[1])

"""repro.compress coverage (docs/compression.md).

* SparseCodec round trip property-tested over k in {1..bucket_size},
  sharded and unsharded, against a per-bucket numpy reference — and the
  measured payload bytes match the static WirePlan exactly;
* the stateless 'plain' algorithm is BIT-exact with the pre-compress
  wire paths: run_compressed(plain) reproduces the frozen PR-3 goldens
  for every topology, and compressed_allreduce(plain) equals
  quantized_allreduce word for word;
* error feedback at a 2-bit uniform grid: the cumulative aggregate
  error contracts vs the stateless wire (the acceptance property), at
  identical wire bits; the warmup gate holds the residual at zero;
* EF on the FSDP chunked reduce-scatter backward: residual round trip
  is exact (new_residual == inp - Q(inp)) and cumulative shard error
  contracts; the 4-arg make_gather threads the residual through the
  custom_vjp under real shard_map;
* make_gather under a PLAIN vmap axis fails fast with an actionable
  error (and the underlying jax-0.4.37 quirk stays pinned by an xfail);
* CompressState checkpoints: save -> restore -> bit-identical next step
  with 'ef' enabled;
* mixed-width re-assignment follows a synthetic bucket-stats shift;
* the ef_vs_plain scenario meets its acceptance claim end to end.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from proptest_compat import given, settings
    from proptest_compat import strategies as st

from repro.compress import (
    CompressState,
    EFAlgorithm,
    SparseCodec,
    make_algorithm,
    sparse_codec_for_scheme,
)
from repro.core.codec import codec_for_scheme, mixed_widths_from_gradient
from repro.core.levels import uniform_levels
from repro.core.schemes import QuantScheme
from repro.dist import fsdp, sync
from repro.kernels import ops
from repro.sim.topology import run_compressed

KEY = jax.random.PRNGKey(11)
SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
GOLDENS = os.path.join(os.path.dirname(__file__), "goldens",
                       "codec_goldens.npz")


def _grad(d, scale=0.01, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (d,)) * scale


def _stacked_state(algo, M, d):
    return jax.vmap(lambda _: algo.init_state(d))(jnp.arange(M))


# ---------------------------------------------------------------------------
# SparseCodec: round trip + exact wire accounting
# ---------------------------------------------------------------------------

def _sparse_reference(vb, codec, levels, key):
    """Per-bucket numpy reference: top-k by |v| (ties -> lower index,
    matching lax.top_k), quantized on the same grid with the same u."""
    nb, bs = vb.shape
    idx = np.stack([np.argsort(-np.abs(np.asarray(vb[b])),
                               kind="stable")[:codec.k]
                    for b in range(nb)])
    idx.sort(axis=1)
    sel = np.take_along_axis(np.asarray(vb), idx, axis=1)
    u = jax.random.uniform(key, sel.shape, jnp.float32)
    c, n = ops.quantize_op(jnp.asarray(sel), u, levels,
                           norm_type=codec.norm_type, use_pallas=False)
    if codec.norm_dtype == "float16":
        n = n.astype(jnp.float16).astype(jnp.float32)
    dq = np.asarray(ops.dequantize_op(c, n, levels, use_pallas=False))
    ref = np.zeros((nb, bs), np.float32)
    np.put_along_axis(ref, idx, dq, axis=1)
    return ref


@settings(max_examples=12, deadline=None)
@given(bs_pow=st.integers(3, 6), k_frac=st.floats(0.0, 1.0),
       seed=st.integers(0, 10_000), sharded=st.sampled_from([False, True]),
       norm_dtype=st.sampled_from(["float32", "float16"]))
def test_sparse_roundtrip_property(bs_pow, k_frac, seed, sharded,
                                   norm_dtype):
    bs = 2 ** bs_pow
    k = max(1, min(bs, int(round(k_frac * bs))))
    codec = SparseCodec(num_levels=8, bucket_size=bs, norm_type="l2",
                        norm_dtype=norm_dtype, k=k)
    lv = uniform_levels(3)
    flat = _grad(16 * bs + seed % bs, seed=seed)  # ragged tail -> padding
    shards = 4 if sharded else 1
    plan = codec.plan(flat.shape[0], shards=shards)
    vb = codec.bucketize(flat, plan)
    key = jax.random.fold_in(KEY, seed)
    pay = codec.encode(vb, lv, key, plan, use_pallas=False)

    # measured wire bytes == the static plan, exactly (per segment)
    if sharded:
        assert pay.words.shape == (shards, plan.code_words)
        assert pay.norm_words.shape == (shards, plan.norm_words)
    else:
        assert pay.words.shape == (plan.code_words,)
        assert pay.norm_words.shape == (plan.norm_words,)
    assert 4 * (pay.words.shape[-1] + pay.norm_words.shape[-1]) \
        == plan.payload_bytes

    ref = _sparse_reference(vb, codec, lv, key)
    if sharded:
        got = np.asarray(codec.decode(pay, lv, plan, shard=None,
                                      use_pallas=False)).reshape(-1)
    else:
        got = np.asarray(codec.decode(pay, lv, plan, use_pallas=False))
    np.testing.assert_array_equal(got, ref.reshape(-1))


@pytest.mark.parametrize("k", [1, 7, 64])
def test_sparse_k_edges_and_full_k_keeps_everything(k):
    bs = 64
    codec = SparseCodec(num_levels=8, bucket_size=bs, norm_type="l2", k=k)
    lv = uniform_levels(3)
    flat = _grad(8 * bs, seed=3)
    plan = codec.plan(flat.shape[0])
    vb = codec.bucketize(flat, plan)
    pay = codec.encode(vb, lv, KEY, plan, use_pallas=False)
    got = np.asarray(codec.decode(pay, lv, plan, use_pallas=False))
    nonzero_per_bucket = (got.reshape(plan.nb, bs) != 0).sum(axis=1)
    assert (nonzero_per_bucket <= k).all()
    if k == bs:
        # k == bucket_size degenerates to the dense round trip: every
        # coordinate survives selection
        u = jax.random.uniform(KEY, vb.shape, jnp.float32)
        c, n = ops.quantize_op(vb, u, lv, norm_type="l2",
                               use_pallas=False)
        ref = ops.dequantize_op(c, n, lv, use_pallas=False)
        np.testing.assert_array_equal(got, np.asarray(ref).reshape(-1))


def test_sparse_codec_validates_k():
    with pytest.raises(ValueError):
        SparseCodec(bucket_size=64, k=0)
    with pytest.raises(ValueError):
        SparseCodec(bucket_size=64, k=65)


def test_topk_rejects_explicit_codec():
    """topk owns its SparseCodec; composing it with a configured codec
    (e.g. mixed_width) is a config conflict, not a silent override."""
    from repro.core.codec import MixedWidthCodec
    scheme = QuantScheme(name="alq", bits=3, bucket_size=256)
    mixed = MixedWidthCodec(bucket_size=256, norm_type="l2",
                            widths=(2, 4))
    with pytest.raises(ValueError, match="SparseCodec"):
        make_algorithm("topk", scheme, codec=mixed)
    # ef DOES compose with any dense codec
    assert make_algorithm("ef", scheme, codec=mixed).codec is mixed


def test_make_gather_rejects_warmup_and_keeps_4arg_contract():
    import inspect
    scheme = QuantScheme(name="qsgdinf", bits=2, bucket_size=256)
    with pytest.raises(ValueError, match="warmup"):
        fsdp.make_gather(("w",), scheme, "quantized",
                         algorithm=make_algorithm("ef:5", scheme))
    # the 4-arg signature survives the fp32 debug toggle
    g = fsdp.make_gather(("w",), scheme, "fp32",
                         algorithm=make_algorithm("ef", scheme))
    assert len(inspect.signature(g).parameters) == 4
    # a stateless algorithm keeps the stateless 3-arg gather
    g3 = fsdp.make_gather(("w",), scheme, "quantized",
                          algorithm=make_algorithm("plain", scheme))
    assert len(inspect.signature(g3).parameters) == 3


def test_equal_budget_default_k():
    """sparse_codec_for_scheme(k=None) never ships more than the dense
    fixed-width symbol budget."""
    for bits in (1, 2, 3, 4, 8):
        for bs in (256, 512, 8192):
            scheme = QuantScheme(name="qsgdinf", bits=bits, bucket_size=bs)
            sc = sparse_codec_for_scheme(scheme)
            dense = codec_for_scheme(scheme)
            assert sc.nominal_bits_per_coord \
                <= dense.nominal_bits_per_coord + 1e-9


# ---------------------------------------------------------------------------
# plain: bit-exact with the pre-compress wire (the PR-3 goldens)
# ---------------------------------------------------------------------------

M, D = 4, 6000


@pytest.fixture(scope="module")
def goldens():
    return np.load(GOLDENS)


@pytest.fixture(scope="module")
def setup():
    scheme = QuantScheme(name="alq", bits=3, bucket_size=256)
    grads = jax.random.normal(jax.random.PRNGKey(0), (M, D)) * 0.01
    return scheme, scheme.init_state(), grads, jax.random.PRNGKey(7)


@pytest.mark.parametrize("topo,kw", [
    ("allreduce", dict(sync_mode="all_gather")),
    ("allreduce", dict(sync_mode="two_phase")),
    ("param_server", dict(server_bits=8)),
    ("ring", {}),
])
def test_plain_algorithm_bit_exact_vs_goldens(goldens, setup, topo, kw):
    scheme, state, grads, key = setup
    algo = make_algorithm("plain", scheme)
    comp = _stacked_state(algo, M, D)
    res, new_comp = run_compressed(topo, grads, scheme, state, algo,
                                   comp, key, use_pallas=False, **kw)
    name = topo + "_" + kw.get("sync_mode", "x")
    np.testing.assert_array_equal(np.asarray(res.aggregate),
                                  goldens[f"agg_{name}"])
    np.testing.assert_array_equal(np.asarray(res.sent_bytes),
                                  goldens[f"sent_{name}"])
    np.testing.assert_array_equal(np.asarray(res.quant_error),
                                  goldens[f"qerr_{name}"])
    # the stateless state advanced its counter and nothing else
    assert new_comp.residual.shape == (M, 0)
    np.testing.assert_array_equal(np.asarray(new_comp.step),
                                  np.ones(M, np.int32))


def test_compressed_allreduce_plain_equals_quantized_allreduce(setup):
    scheme, state, grads, key = setup
    algo = make_algorithm("plain", scheme)
    comp = _stacked_state(algo, M, D)
    out_c, _, m_c = jax.vmap(
        lambda g, c: sync.compressed_allreduce(
            g, scheme, state, algo, c, key, axes=("w",),
            use_pallas=False),
        axis_name="w")(grads, comp)
    out_q, m_q = jax.vmap(
        lambda g: sync.quantized_allreduce(
            g, scheme, state, key, axes=("w",), use_pallas=False),
        axis_name="w")(grads)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_q))
    np.testing.assert_array_equal(np.asarray(m_c.comm_bits_per_coord),
                                  np.asarray(m_q.comm_bits_per_coord))


# ---------------------------------------------------------------------------
# error feedback: cumulative contraction, zero wire cost, warmup
# ---------------------------------------------------------------------------

def _cumulative_error(spec, scheme, T=10, mode="all_gather"):
    state = scheme.init_state()
    algo = make_algorithm(spec, scheme)
    comp = _stacked_state(algo, M, D)
    base = jax.random.normal(jax.random.PRNGKey(1), (M, D)) * 0.01
    step = jax.jit(jax.vmap(
        lambda g, c, k: sync.compressed_allreduce(
            g, scheme, state, algo, c, k, axes=("w",), mode=mode,
            use_pallas=False),
        axis_name="w", in_axes=(0, 0, None)))
    cum = np.zeros(D)
    bits = None
    for t in range(T):
        g = base + jax.random.normal(
            jax.random.PRNGKey(100 + t), (M, D)) * 0.002
        out, comp, m = step(g, comp, jax.random.fold_in(KEY, t))
        cum += np.asarray(out)[0] - np.asarray(g).mean(0)
        bits = float(m.comm_bits_per_coord[0])
    return float((cum ** 2).sum()), bits


@pytest.mark.parametrize("mode", ["all_gather", "two_phase"])
def test_ef_contracts_cumulative_error_at_2bit(mode):
    scheme = QuantScheme(name="qsgdinf", bits=2, bucket_size=256)
    e_plain, b_plain = _cumulative_error("plain", scheme, mode=mode)
    e_ef, b_ef = _cumulative_error("ef", scheme, mode=mode)
    assert e_ef < e_plain  # strictly lower, the acceptance property
    assert b_ef == b_plain  # the residual travels exactly zero bytes


def test_topk_bounds_cumulative_error_at_equal_bits():
    scheme = QuantScheme(name="qsgdinf", bits=2, bucket_size=512)
    e_plain, b_plain = _cumulative_error("plain", scheme, T=20)
    e_topk, b_topk = _cumulative_error("topk", scheme, T=20)
    assert e_topk < e_plain
    assert b_topk <= b_plain + 1e-6  # never over the dense budget


def test_ef_warmup_gate_holds_residual_at_zero():
    scheme = QuantScheme(name="qsgdinf", bits=2, bucket_size=256)
    algo = make_algorithm("ef:3", scheme)
    assert isinstance(algo, EFAlgorithm) and algo.warmup_steps == 3
    comp = _stacked_state(algo, M, D)
    g = jax.random.normal(jax.random.PRNGKey(1), (M, D)) * 0.01
    for t in range(5):
        _, comp, m = jax.vmap(
            lambda gg, c: sync.compressed_allreduce(
                gg, scheme, scheme.init_state(), algo, c,
                jax.random.fold_in(KEY, t), axes=("w",),
                use_pallas=False),
            axis_name="w")(g, comp)
        rn = float(m.residual_norm[0])
        if t < 3:
            assert rn == 0.0
        else:
            assert rn > 0.0


# ---------------------------------------------------------------------------
# EF on the FSDP chunked reduce-scatter backward
# ---------------------------------------------------------------------------

def test_fsdp_rs_residual_is_exact_own_roundtrip():
    """new_residual == inp - Q(inp), where Q is the decode of the very
    payloads the worker shipped (all chunked rounds assembled)."""
    scheme = QuantScheme(name="qsgdinf", bits=2, bucket_size=256)
    codec = codec_for_scheme(scheme)
    lv = scheme.init_state().levels
    gf = jax.random.normal(jax.random.PRNGKey(3), (M, 8192)) * 0.01
    r0 = jax.random.normal(jax.random.PRNGKey(4), (M, 8192)) * 0.003

    rs, new_r = jax.vmap(
        lambda x, r: fsdp._quantized_reduce_scatter(
            x, lv, KEY, axes=("w",), codec=codec, use_pallas=False,
            residual=r),
        axis_name="w")(gf, r0)
    assert rs.shape == (M, 2048) and new_r.shape == (M, 8192)
    inp = np.asarray(gf) + np.asarray(r0)
    q_inp = inp - np.asarray(new_r)      # the implied own round trip
    # Q is a genuine quantization of inp: bounded error, and the shard
    # means of Q(inp) reproduce the reduce-scatter output exactly
    assert ((q_inp - inp) ** 2).sum() < (inp ** 2).sum()
    own_mean = q_inp.reshape(M, M, 2048).mean(0)
    np.testing.assert_allclose(np.asarray(rs), own_mean, rtol=1e-6,
                               atol=1e-7)


def test_fsdp_rs_ef_contracts_cumulative_shard_error():
    scheme = QuantScheme(name="qsgdinf", bits=2, bucket_size=256)
    codec = codec_for_scheme(scheme)
    lv = scheme.init_state().levels
    gf = jax.random.normal(jax.random.PRNGKey(3), (M, 8192)) * 0.01
    ref = np.asarray(gf).mean(0).reshape(M, -1)

    def cum_err(ef, T=6):
        resid = jnp.zeros((M, 8192))
        cum = np.zeros((M, 2048))
        for t in range(T):
            key = jax.random.fold_in(jax.random.PRNGKey(9), t)
            if ef:
                rs, resid = jax.vmap(
                    lambda x, r: fsdp._quantized_reduce_scatter(
                        x, lv, key, axes=("w",), codec=codec,
                        use_pallas=False, residual=r),
                    axis_name="w")(gf, resid)
            else:
                rs = jax.vmap(
                    lambda x: fsdp._quantized_reduce_scatter(
                        x, lv, key, axes=("w",), codec=codec,
                        use_pallas=False),
                    axis_name="w")(gf)
            cum += np.asarray(rs) - ref
        return float((cum ** 2).sum())

    assert cum_err(True) < cum_err(False)


def test_make_gather_ef_under_shard_map():
    """The 4-arg EF gather end to end under real shard_map on 4 fake
    devices: the residual's 'cotangent' IS the new EF memory, and it
    matches the direct (vmap) _quantized_reduce_scatter reference."""
    body = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compress import make_algorithm
from repro.core.schemes import QuantScheme
from repro.dist import fsdp

M, Lp = 4, 4096
scheme = QuantScheme(name="qsgdinf", bits=2, bucket_size=256)
algo = make_algorithm("ef", scheme)
gather = fsdp.make_gather(("w",), scheme, "quantized",
                          use_pallas=False, algorithm=algo)
lv = scheme.init_state().levels
key = jax.random.PRNGKey(11)
mesh = jax.make_mesh((4,), ("w",))
shards = jax.random.normal(jax.random.PRNGKey(5), (Lp,))
target = jnp.asarray(
    np.asarray(jax.random.normal(jax.random.PRNGKey(6), (Lp,))) * 0.01)
r0 = jax.random.normal(jax.random.PRNGKey(8), (M, Lp)) * 0.003

def worker_loss(s, r, t):
    full = gather(s, lv, key, r)
    return jnp.sum((full - t) ** 2)

def worker(s, r, t):
    ds, new_r = jax.grad(worker_loss, argnums=(0, 1))(s, r[0], t)
    return ds, new_r[None]

f = jax.jit(jax.shard_map(
    worker, mesh=mesh, in_specs=(P("w"), P("w", None), P()),
    out_specs=(P("w"), P("w", None)), check_vma=False))
ds, new_r = f(shards, r0, target)
assert ds.shape == (Lp,) and new_r.shape == (M, Lp)

# reference: the plain (non-custom_vjp) function under vmap with the
# same cotangent: the gathered full vector IS `shards`, so the loss
# cotangent w.r.t. it is 2*(shards - target) on every worker
cotangent = 2.0 * (shards - target)
rs_ref, new_r_ref = jax.vmap(
    lambda r: fsdp._quantized_reduce_scatter(
        cotangent, lv, key, axes=("w",), codec=algo.codec,
        use_pallas=False, residual=r),
    axis_name="w")(r0)
np.testing.assert_array_equal(np.asarray(new_r), np.asarray(new_r_ref))
np.testing.assert_array_equal(
    np.asarray(ds), np.asarray(rs_ref).reshape(-1))
print("EF_GATHER_OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"OUT:{proc.stdout}\nERR:{proc.stderr}"
    assert "EF_GATHER_OK" in proc.stdout


# ---------------------------------------------------------------------------
# the plain-vmap quirk: fail fast + pin the raw behavior
# ---------------------------------------------------------------------------

def _vmap_gather_grad(guard_vmap):
    scheme = QuantScheme(name="alq", bits=3, bucket_size=256)
    gather = fsdp.make_gather(("w",), scheme, "quantized",
                              use_pallas=False, guard_vmap=guard_vmap)
    lv = scheme.init_state().levels
    shards = jax.random.normal(jax.random.PRNGKey(5), (4, 2048))

    def worker_loss(s):
        return jnp.sum(gather(s, lv, KEY) ** 2)

    return jax.vmap(jax.grad(worker_loss), axis_name="w")(shards)


def test_make_gather_under_plain_vmap_raises_actionable():
    with pytest.raises(NotImplementedError, match="shard_map"):
        _vmap_gather_grad(guard_vmap=True)


@pytest.mark.xfail(strict=True, raises=Exception,
                   reason="jax-0.4.37 custom_vjp x all_to_all batching "
                          "quirk: vmap's batching rule mis-shapes the "
                          "backward's collective (pinned; if this "
                          "XPASSes after a jax upgrade, the guard in "
                          "make_gather can be retired)")
def test_make_gather_under_plain_vmap_quirk_pinned():
    _vmap_gather_grad(guard_vmap=False)


# ---------------------------------------------------------------------------
# CompressState checkpoint round trip (train/checkpoint.py)
# ---------------------------------------------------------------------------

def _train_harness(compress, steps, state=None, seed=0):
    from jax.sharding import PartitionSpec as P
    from repro import configs
    from repro.models import Model
    from repro.train.data import DataConfig, Pipeline
    from repro.train.optim import OptimConfig
    from repro.train.train_step import (
        TrainConfig, TrainState, compress_state_specs, init_train_state,
        make_train_step, metric_specs)

    cfg = configs.get_config("paper-proxy")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = Model(cfg, tp=1, dp=1)
    tcfg = TrainConfig(
        scheme=QuantScheme(name="qsgdinf", bits=2, bucket_size=1024),
        optim=OptimConfig(name="adamw", lr=1e-3, weight_decay=0.0),
        update_milestones=(2,), update_every=0, compress=compress)
    step_fn = make_train_step(model, tcfg, data_axes=("data",))
    pipe = Pipeline(DataConfig(kind="markov", vocab_size=cfg.vocab_size,
                               seq_len=32, global_batch=4, seed=seed))
    pspecs = model.param_specs()
    with jax.set_mesh(mesh):
        if state is None:
            state = init_train_state(model, tcfg,
                                     jax.random.PRNGKey(seed))
        sspecs = TrainState(
            params=pspecs, opt=type(state.opt)(
                mu=pspecs,
                nu=None if state.opt.nu is None else pspecs, count=P()),
            scheme_state=jax.tree.map(lambda _: P(), state.scheme_state),
            step=P(), rng=P(),
            compress_state=compress_state_specs(state, ("data",)))
        train = jax.jit(jax.shard_map(
            step_fn,
            in_specs=(sspecs, {"ids": P("data"), "labels": P("data")}),
            out_specs=(sspecs, metric_specs()), check_vma=False))
        metrics = None
        for t in range(steps):
            base = int(state.step)
            state, metrics = train(state, pipe.batch(base))
    return state, metrics


def test_compress_state_checkpoint_roundtrip(tmp_path):
    from repro.train import checkpoint

    state, _ = _train_harness("ef", steps=3)
    assert state.compress_state is not None
    assert float(CompressState(*state.compress_state).residual_norm) > 0
    assert int(state.compress_state.step) == 3

    path = os.path.join(tmp_path, "ckpt.npz")
    checkpoint.save(path, state)
    restored = checkpoint.restore(path, state)
    np.testing.assert_array_equal(
        np.asarray(restored.compress_state.residual),
        np.asarray(state.compress_state.residual))

    # one more step from the live state and from the restored state must
    # be BIT-identical (params, residual, metrics)
    from jax.flatten_util import ravel_pytree
    s1, m1 = _train_harness("ef", steps=1, state=state)
    s2, m2 = _train_harness("ef", steps=1, state=restored)
    f1, _ = ravel_pytree(s1.params)
    f2, _ = ravel_pytree(s2.params)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(
        np.asarray(s1.compress_state.residual),
        np.asarray(s2.compress_state.residual))
    assert float(m1["loss"]) == float(m2["loss"])


@pytest.mark.parametrize("compress", ["ef", "topk"])
def test_train_step_with_compression_trains(compress):
    state, metrics = _train_harness(compress, steps=4)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["residual_norm"]) > 0
    kept = float(metrics["kept_fraction"])
    assert kept == 1.0 if compress == "ef" else kept < 1.0
    assert int(state.compress_state.step) == 4


# ---------------------------------------------------------------------------
# mixed-width re-assignment under drifting stats (satellite)
# ---------------------------------------------------------------------------

def test_width_assignment_tracks_stats_shift():
    """The same probe protocol the sim's milestone cadence runs: when
    the per-bucket scale profile flips, the bit assignment follows the
    heavy buckets."""
    scheme = QuantScheme(name="alq", bits=3, bucket_size=256)
    nb = 16
    scales = np.geomspace(1e-3, 1.0, nb).astype(np.float32)
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (nb, 256)))
    w_up = mixed_widths_from_gradient((g * scales[:, None]).reshape(-1),
                                      scheme)
    w_down = mixed_widths_from_gradient(
        (g * scales[::-1][:, None]).reshape(-1), scheme)
    assert w_up != w_down
    # bits follow the heavy end in both profiles
    assert np.mean(w_up[-4:]) > np.mean(w_up[:4])
    assert np.mean(w_down[:4]) > np.mean(w_down[-4:])


# ---------------------------------------------------------------------------
# scenario acceptance: ef_vs_plain end to end
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_ef_vs_plain_scenario_acceptance():
    from repro.sim import SCENARIOS, run_scenario

    out = run_scenario(SCENARIOS["ef_vs_plain"], steps=6, workers=4)
    cum = {c["compress"]: c["totals"]["final_cum_agg_err"]
           for c in out["cells"]}
    assert set(cum) == {"plain", "ef"}
    assert cum["ef"] < cum["plain"]
    for c in out["cells"]:
        assert all("residual_norm" in s for s in c["steps"])

"""Serving correctness: prefill + N decode steps reproduce the full-
sequence forward logits for every attention flavour and recurrent
family (KV-cache ring addressing, RWKV/Mamba state carry, cross-attn)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import Model, ModelConfig
from repro.models.layers import lm_head_logits, rms_norm

BASE = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
            d_ff=128, vocab_size=256, compute_dtype="float32")

CONFIGS = [
    ModelConfig(name="dense", arch_type="dense", **BASE),
    ModelConfig(name="sliding", arch_type="dense", attn_kind="sliding",
                window=8, **BASE),
    ModelConfig(name="chunked", arch_type="dense", attn_kind="chunked",
                chunk=8, **BASE),
    ModelConfig(name="rwkv", arch_type="ssm", layer_pattern="rwkv",
                rwkv_head_dim=32, **BASE),
    ModelConfig(name="hybrid-moe", arch_type="hybrid",
                layer_pattern="mamba_hybrid", attn_every=2, moe=True,
                num_experts=4, top_k=2, moe_every=2, capacity_factor=8.0,
                **{**BASE, "num_layers": 4}),
    ModelConfig(name="vlm", arch_type="vlm", cross_attn_every=2, **BASE),
]


@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: c.name)
def test_prefill_decode_matches_forward(cfg):
    S, n_decode, max_len = 24, 3, 64
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    m = Model(cfg, tp=1, dp=1)
    params = m.init(jax.random.PRNGKey(0))
    B = 2
    ids = jax.random.randint(jax.random.PRNGKey(1), (B, S + n_decode), 0,
                             cfg.vocab_size)
    vision = None
    vspec = None
    if cfg.cross_attn_every:
        vision = jax.random.normal(jax.random.PRNGKey(3),
                                   (B, 8, cfg.d_model), jnp.float32)
        vspec = P("data")
    pspecs = m.param_specs()
    cspec = jax.tree.map(lambda _: P(),
                         jax.eval_shape(lambda: m.init_cache(B, max_len, 1)))

    def full_logits(p, ids, vision):
        x, _ = m.forward(p, ids, vision)
        x = rms_norm(x, p["final_norm"], cfg.norm_eps)
        return lm_head_logits(m.ctx, p["lm_head"].squeeze(0), x[:, -1],
                              cfg.vocab_size)

    with jax.set_mesh(mesh):
        smap = lambda f, i, o: jax.jit(
            jax.shard_map(f, in_specs=i, out_specs=o, check_vma=False))
        ref = smap(full_logits, (pspecs, P("data"), vspec), P("data"))
        pf = smap(lambda p, i, v: m.prefill(p, i, v, max_len=max_len,
                                            cache_shards=1),
                  (pspecs, P("data"), vspec), (P("data"), cspec))
        df = smap(lambda p, t, pos, c, v: m.decode(p, t, pos, c, v,
                                                   cache_shards=1),
                  (pspecs, P("data"), P("data"), cspec, vspec),
                  (P("data"), cspec))

        logits, caches = pf(params, ids[:, :S], vision)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref(params, ids[:, :S], vision)),
            rtol=3e-4, atol=3e-4)
        for t in range(S, S + n_decode):
            logits, caches = df(params, ids[:, t],
                                jnp.full((B,), t, jnp.int32), caches,
                                vision)
            want = ref(params, ids[:, : t + 1], vision)
            np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                                       rtol=4e-3, atol=4e-3,
                                       err_msg=f"{cfg.name} step {t}")

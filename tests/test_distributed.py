"""Multi-device semantics, run in subprocesses with 8 fake CPU devices
(XLA_FLAGS must be set before jax import, and only for these tests —
the rest of the suite sees one device):

  * quantized allreduce (both wire modes) is unbiased and all workers
    agree bit-exactly;
  * FSDP + fp32 reduce-scatter reproduces pure-DP fp32 gradients;
  * a reduced multi-pod dry-run (2x2x2 mesh) lowers and compiles for a
    train and a decode shape.
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_script(body: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_quantized_allreduce_unbiased_and_consistent():
    out = run_script(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.sync import quantized_allreduce
from repro.core.schemes import QuantScheme

mesh = jax.make_mesh((2, 4), ("pod", "data"))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 2048)) * 0.01
fp = g.mean(0)
scheme = QuantScheme(name="alq", bits=4, bucket_size=256)
state = scheme.init_state()
for mode in ("all_gather", "two_phase"):
    def f(gl, key):
        out, _ = quantized_allreduce(gl.reshape(-1), scheme, state, key,
                                     axes=("pod", "data"), mode=mode)
        return out
    # out_specs P(None): replicated output -> jax checks all-device agreement
    smf = jax.jit(jax.shard_map(f, mesh=mesh,
        in_specs=(P(("pod", "data")), P()), out_specs=P(), check_vma=False))
    outs = [np.asarray(smf(g, jax.random.PRNGKey(i))) for i in range(24)]
    est = np.mean(outs, 0)
    err = np.abs(est - np.asarray(fp)).max()
    one = np.abs(outs[0] - np.asarray(fp)).max()
    assert err < one / 2.5, (mode, err, one)
print("SYNC_OK")
""")
    assert "SYNC_OK" in out


def test_fsdp_fp32_matches_pure_dp():
    out = run_script(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.models import Model, ModelConfig
from repro.core.schemes import QuantScheme

cfg = ModelConfig(name="t", arch_type="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  compute_dtype="float32")
mesh = jax.make_mesh((4, 2), ("data", "model"))
ids = jax.random.randint(jax.random.PRNGKey(0), (8, 16), 0, 256)
labels = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 256)

def grads_for(param_mode):
    m = Model(cfg, tp=2, dp=4, param_mode=param_mode,
              fsdp_scheme=QuantScheme(name="fp32", bucket_size=256),
              fsdp_sync="fp32")
    params = m.init(jax.random.PRNGKey(42))
    pspecs = m.param_specs()
    def lossf(p, i, l):
        loss = m.loss(p, {"ids": i, "labels": l})
        g = jax.grad(lambda q: m.loss(q, {"ids": i, "labels": l}))(p)
        g.pop("final_norm")  # replicated leaf: compared via flat parts only
        if param_mode == "dp":
            gf = jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(g)])
            gf = jax.lax.psum(gf, ("data",)) / 4
        else:
            gf = jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(g)])
        return loss, gf
    f = jax.jit(jax.shard_map(lossf, mesh=mesh,
        in_specs=(pspecs, P("data"), P("data")),
        out_specs=(P(), P() if param_mode == "dp" else P(("data",))),
        check_vma=False))
    return f(params, ids, labels)

l_dp, g_dp = grads_for("dp")
l_fs, g_fs = grads_for("fsdp")
# identical init => identical loss
np.testing.assert_allclose(float(l_dp), float(l_fs), rtol=1e-5)
# gradient *norms* agree (layouts differ: dp tree vs fsdp flat+padding)
n_dp = float(jnp.sqrt(jnp.sum(g_dp**2)))
n_fs = float(jnp.sqrt(jnp.sum(g_fs**2)))
np.testing.assert_allclose(n_dp, n_fs, rtol=1e-3)
print("FSDP_OK", n_dp, n_fs)
""")
    assert "FSDP_OK" in out


@pytest.mark.slow
def test_reduced_multipod_dryrun():
    out = run_script(r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import configs
from repro.configs.shapes import InputShape
from repro.launch import dryrun

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = configs.get_smoke_config("llama3.2-1b")
for shape in (InputShape("t", 64, 8, "train"),
              InputShape("d", 128, 8, "decode")):
    compiled, acost, tl, tc = dryrun.lower_pair(cfg, shape, mesh, bits=3)
    assert compiled.cost_analysis() is not None
    assert acost.flops > 0
print("DRYRUN_OK")
""")
    assert "DRYRUN_OK" in out

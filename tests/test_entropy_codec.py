"""Cross-layer conformance suite for the entropy-coded wire
(docs/wire_format.md, "Entropy-coded payload family").

The ``EntropyCodec`` shares the quantize kernel and the key schedule
with ``UniformCodec`` — only the symbol *packaging* differs, and
entropy coding is lossless on symbols — so every decoded value must be
BIT-exact with the uncoded uniform path.  Pinned here:

* payload round trip against the uniform codec at every width 1..8 x
  {fp32, fp16} norms, unsharded and sharded (diagonal decode);
* every wire mode: ``run_topology`` allreduce (all_gather + two_phase),
  param_server, ring on 8 logical workers, plus the real shard_map
  paths (both allreduce modes + the FSDP chunked reduce-scatter) on 8
  fake devices in a subprocess;
* the forced-fallback path: a table built from adversarially skewed
  occupancies fed uniform-occupancy data overflows every bucket's
  capacity -> per-bucket fixed-width fallback (flag bit), still
  bit-exact, measured == capacity-ish;
* ``compress='ef'`` stacked on top decodes bit-exact against ef over
  the uniform codec (aggregates AND residual states); ``topk`` owns its
  SparseCodec, so an explicit entropy codec raises the config conflict;
* measured-volume accounting: ``measured_bits_per_coord`` == the plan
  for full-capacity payloads, strictly below the fixed-width plan for
  a fitted table on gaussian gradients, and consistent between the
  sharded and unsharded layouts of the same gradient;
* ``SyncMetrics`` / ``SyncMetricsLite`` / ``SchemeState`` metric-dtype
  pinning: every defaulted field is a float32 scalar, never a Python
  float, on every path including fp32 / no-update.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import make_algorithm
from repro.core.codec import (
    EntropyCodec,
    UniformCodec,
    codec_for_scheme,
    entropy_codec_from_gradient,
    entropy_wrap,
    make_codec,
)
from repro.core.levels import num_levels, uniform_levels
from repro.core.schemes import QuantScheme, SchemeState
from repro.dist import fsdp, sync
from repro.sim.topology import run_compressed, run_topology

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
KEY = jax.random.PRNGKey(11)
M, D, BS = 8, 6000, 256


def _scheme(bits=3, **kw):
    return QuantScheme(name="alq", bits=bits, bucket_size=BS, **kw)


def _grads(seed=0, m=M, d=D, scale=0.01):
    return jax.random.normal(jax.random.PRNGKey(seed), (m, d)) * scale


def _fitted(scheme, flat, levels):
    return entropy_codec_from_gradient(flat, scheme, levels)


# ---------------------------------------------------------------------------
# codec-level conformance: decoded values == uniform codec, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", range(1, 9))
@pytest.mark.parametrize("norm_dtype", ["float32", "float16"])
def test_roundtrip_matches_uniform_all_widths(bits, norm_dtype):
    uc = UniformCodec(num_levels=num_levels(bits), bucket_size=64,
                      norm_type="l2", norm_dtype=norm_dtype)
    ec = entropy_wrap(uc)  # cold-start table
    lv = uniform_levels(bits)
    flat = _grads(seed=bits, m=1, d=1000 + bits)[0]
    pu, pe = uc.plan(flat.shape[0]), ec.plan(flat.shape[0])
    assert pe.variable and not pu.variable
    pay = ec.encode(ec.bucketize(flat, pe), lv, KEY, pe,
                    use_pallas=False)
    assert pay.words.shape == (pe.code_words,)
    ref = uc.decode(uc.encode(uc.bucketize(flat, pu), lv, KEY, pu,
                              use_pallas=False), lv, pu,
                    use_pallas=False)
    got = ec.decode(pay, lv, pe, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_sharded_diagonal_decode_matches_uniform():
    scheme = _scheme()
    lv = scheme.init_state().levels
    uc = codec_for_scheme(scheme)
    ec = entropy_wrap(uc)
    flat = _grads(m=1, d=32 * BS)[0]
    pu = uc.plan(flat.shape[0], shards=4)
    pe = ec.plan(flat.shape[0], shards=4)
    payu = uc.encode(uc.bucketize(flat, pu), lv, KEY, pu,
                     use_pallas=False)
    paye = ec.encode(ec.bucketize(flat, pe), lv, KEY, pe,
                     use_pallas=False)
    assert paye.words.shape == (4, pe.code_words)
    ou = np.asarray(uc.decode(payu, lv, pu, shard=None,
                              use_pallas=False))
    oe = np.asarray(ec.decode(paye, lv, pe, shard=None,
                              use_pallas=False))
    np.testing.assert_array_equal(ou, oe)
    # static per-shard decode agrees with the diagonal (every segment
    # shares one static layout; no lax.switch needed)
    for s in range(4):
        one = ec.decode(jax.tree.map(lambda a: a[s][None], paye), lv,
                        pe, shard=s, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(one)[0], oe[s])


# ---------------------------------------------------------------------------
# wire-mode conformance on 8 logical workers (vmap named axes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo,kw", [
    ("allreduce", dict(sync_mode="all_gather")),
    ("allreduce", dict(sync_mode="two_phase")),
    ("param_server", dict(server_bits=8)),
    ("param_server", dict(server_bits=None)),
    ("ring", {}),
])
def test_topology_conformance_vs_uniform(topo, kw):
    scheme = _scheme()
    state = scheme.init_state()
    grads = _grads()
    ec = _fitted(scheme, grads[0], state.levels)
    r_u = run_topology(topo, grads, scheme, state, KEY,
                       use_pallas=False, **kw)
    r_e = run_topology(topo, grads, scheme, state, KEY, codec=ec,
                       use_pallas=False, **kw)
    np.testing.assert_array_equal(np.asarray(r_u.aggregate),
                                  np.asarray(r_e.aggregate))
    np.testing.assert_array_equal(np.asarray(r_u.quant_error),
                                  np.asarray(r_e.quant_error))
    # the entropy wire never bills MORE than the uniform plan shipped
    # (headers cost 32/bucket_size; the coded runs more than pay it
    # back on gaussian gradients), except the capacity-billed ring
    if topo != "ring":
        assert (np.asarray(r_e.wire_bits_per_coord)
                <= np.asarray(r_u.wire_bits_per_coord) + 1e-5).all(), (
            r_e.wire_bits_per_coord, r_u.wire_bits_per_coord)


def test_fsdp_reduce_scatter_conformance():
    """The FSDP chunked quantized reduce-scatter carries coded chunks
    (headers + regions ride the generic payload all-to-all) and decodes
    bit-exact against the uniform codec."""
    scheme = _scheme()
    state = scheme.init_state()
    gf = _grads(seed=3, m=4, d=8192)

    def rs(codec):
        return np.asarray(jax.vmap(
            lambda x: fsdp._quantized_reduce_scatter(
                x, state.levels, KEY, axes=("w",), codec=codec,
                use_pallas=False),
            axis_name="w")(gf))

    uc = codec_for_scheme(scheme)
    ec = _fitted(scheme, gf[0], state.levels)
    assert ec.chunkable  # the k-round overlap re-plans sub-ranges
    np.testing.assert_array_equal(rs(uc), rs(ec))


def test_shard_map_conformance_8_fake_devices():
    """Real mesh collectives: both allreduce wire modes and the FSDP
    reduce-scatter under shard_map on 8 fake devices, entropy vs
    uniform bit-exact."""
    body = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.codec import codec_for_scheme, entropy_codec_from_gradient
from repro.core.schemes import QuantScheme
from repro.dist import fsdp, sync

M, D = 8, 4096
scheme = QuantScheme(name="alq", bits=3, bucket_size=256)
state = scheme.init_state()
mesh = jax.make_mesh((M,), ("data",))
g = jax.random.normal(jax.random.PRNGKey(0), (M, D)) * 0.01
key = jax.random.PRNGKey(7)
uc = codec_for_scheme(scheme)
ec = entropy_codec_from_gradient(np.asarray(g[0]), scheme, state.levels)

for mode in ("all_gather", "two_phase"):
    def f(gl, codec):
        out, m = sync.quantized_allreduce(
            gl.reshape(-1), scheme, state, key, axes=("data",),
            mode=mode, use_pallas=False, codec=codec)
        return out, m.comm_bits_per_coord
    outs = {}
    for name, codec in (("uniform", uc), ("entropy", ec)):
        smf = jax.jit(jax.shard_map(
            lambda gl: f(gl, codec), mesh=mesh,
            in_specs=P("data"), out_specs=(P(), P()), check_vma=False))
        outs[name] = smf(g)
    assert (np.asarray(outs["uniform"][0])
            == np.asarray(outs["entropy"][0])).all(), mode
    assert (float(outs["entropy"][1])
            <= float(outs["uniform"][1]) + 1e-5), mode

def rs(codec):
    smf = jax.jit(jax.shard_map(
        lambda x: fsdp._quantized_reduce_scatter(
            x.reshape(-1), state.levels, key, axes=("data",),
            codec=codec, use_pallas=False),
        mesh=mesh, in_specs=P("data"), out_specs=P("data"),
        check_vma=False))
    return np.asarray(smf(g.reshape(M, -1)))
assert (rs(uc) == rs(ec)).all()
print("ENTROPY_CONFORMANCE_OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"OUT:{proc.stdout}\nERR:{proc.stderr}"
    assert "ENTROPY_CONFORMANCE_OK" in proc.stdout


# ---------------------------------------------------------------------------
# forced fallback: adversarial occupancies overflow the coded capacity
# ---------------------------------------------------------------------------

def test_forced_fallback_is_bit_exact_and_flagged():
    scheme = QuantScheme(name="qsgdinf", bits=3, bucket_size=BS)
    state = scheme.init_state()
    uc = codec_for_scheme(scheme)
    # table fit to "everything is zero" => long codes for every nonzero
    # symbol; uniform-occupancy data (large magnitudes hit all levels)
    # then overflows every bucket's fixed-width capacity
    skew = np.zeros(scheme.num_levels)
    skew[0] = 1.0
    ec = entropy_wrap(uc, skew)
    flat = jax.random.uniform(jax.random.PRNGKey(1), (BS * 16,)) * 2 - 1
    lv = state.levels
    pe, pu = ec.plan(flat.shape[0]), uc.plan(flat.shape[0])
    pay = ec.encode(ec.bucketize(flat, pe), lv, KEY, pe,
                    use_pallas=False)
    flags = np.asarray(pay.words[:pe.shard_nb]) >> 31
    assert flags.all(), "adversarial table must force every bucket back"
    ref = uc.decode(uc.encode(uc.bucketize(flat, pu), lv, KEY, pu,
                              use_pallas=False), lv, pu,
                    use_pallas=False)
    got = ec.decode(pay, lv, pe, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # fallback ships capacity + headers: measured == the entropy plan's
    # own worst case, slightly ABOVE the uniform plan (the header tax)
    mb = float(ec.measured_bits_per_coord(pay, pe))
    assert mb == pytest.approx(pe.bits_per_coord, rel=1e-6)
    assert mb >= pu.bits_per_coord


def test_fitted_table_measures_below_fixed_width():
    scheme = _scheme()
    state = scheme.init_state()
    flat = _grads(m=1, d=64 * BS)[0]
    ec = _fitted(scheme, flat, state.levels)
    uc = codec_for_scheme(scheme)
    pe, pu = ec.plan(flat.shape[0]), uc.plan(flat.shape[0])
    pay = ec.encode(ec.bucketize(flat, pe), state.levels, KEY, pe,
                    use_pallas=False)
    mb = float(ec.measured_bits_per_coord(pay, pe))
    assert mb < pu.bits_per_coord, (mb, pu.bits_per_coord)
    # sharded layout of the same gradient bills (almost) the same bytes
    # (per-segment norm-word alignment only)
    pe4 = ec.plan(flat.shape[0], shards=4)
    pay4 = ec.encode(ec.bucketize(flat, pe4), state.levels, KEY, pe4,
                     use_pallas=False)
    mb4 = float(ec.measured_bits_per_coord(pay4, pe4))
    assert mb4 == pytest.approx(mb, rel=0.02)


# ---------------------------------------------------------------------------
# compress algorithms stacked on the entropy codec
# ---------------------------------------------------------------------------

def test_ef_stacked_on_entropy_codec_bit_exact():
    scheme = QuantScheme(name="qsgdinf", bits=2, bucket_size=BS)
    state = scheme.init_state()
    grads = _grads(m=4)
    ec = _fitted(scheme, grads[0], state.levels)

    def run(codec, comp_state):
        algo = make_algorithm("ef", scheme, codec=codec)
        return run_compressed("allreduce", grads, scheme, state, algo,
                              comp_state, KEY, use_pallas=False)

    cs0 = jax.tree.map(
        lambda a: jnp.stack([a] * 4),
        make_algorithm("ef", scheme).init_state(D))
    ru, su = run(codec_for_scheme(scheme), cs0)
    re, se = run(ec, cs0)
    np.testing.assert_array_equal(np.asarray(ru.aggregate),
                                  np.asarray(re.aggregate))
    np.testing.assert_array_equal(np.asarray(su.residual),
                                  np.asarray(se.residual))


def test_topk_rejects_entropy_codec():
    """topk owns its SparseCodec; stacking it on an explicit entropy
    codec is a config conflict, pinned as a raise (not a silent
    discard)."""
    scheme = _scheme()
    ec = entropy_wrap(codec_for_scheme(scheme))
    with pytest.raises(ValueError, match="SparseCodec"):
        make_algorithm("topk", scheme, codec=ec)


def test_entropy_wrap_rejects_non_uniform_bases():
    from repro.core.codec import MixedWidthCodec
    with pytest.raises(ValueError, match="uniform"):
        entropy_wrap(MixedWidthCodec(bucket_size=BS, widths=(2, 4)))
    scheme = _scheme()
    with pytest.raises(ValueError, match="uniform"):
        make_codec(scheme, "entropy:mixed_width")
    assert isinstance(make_codec(scheme, "entropy"), EntropyCodec)
    assert isinstance(make_codec(scheme, "entropy:uniform"),
                      EntropyCodec)


def test_bad_table_raises():
    with pytest.raises(ValueError, match="signed"):
        EntropyCodec(num_levels=8, bucket_size=BS,
                     huff_lengths=(3,), huff_codes=(0,))


# ---------------------------------------------------------------------------
# metric-dtype pinning: no Python floats leak through SyncMetrics
# ---------------------------------------------------------------------------

def _assert_f32_scalar(name, x):
    assert not isinstance(x, (float, int)), (
        f"{name} leaked a Python scalar: {x!r}")
    assert jnp.asarray(x).dtype == jnp.float32, (name, x)


@pytest.mark.parametrize("mode", ["fp32", "all_gather", "two_phase"])
def test_sync_metrics_fields_are_float32(mode):
    scheme = _scheme() if mode != "fp32" else QuantScheme(name="fp32")
    state = scheme.init_state()
    flat = _grads(m=1, d=4 * BS)[0]
    _, m = sync.quantized_allreduce(flat, scheme, state, KEY, axes=(),
                                    mode=mode, use_pallas=False)
    for name, val in zip(m._fields, m):
        _assert_f32_scalar(name, val)


def test_metric_defaults_are_float32_scalars():
    """The no-update / stateless construction paths: defaulted
    NamedTuple fields must already be float32 scalars."""
    from repro.train.train_step import SyncMetricsLite
    m = sync.SyncMetrics(jnp.float32(1.0), jnp.float32(0.0),
                         jnp.float32(1.0), jnp.float32(0.0))
    for name in ("entropy_bits_per_coord", "residual_norm",
                 "kept_fraction"):
        _assert_f32_scalar(name, getattr(m, name))
    lite = SyncMetricsLite(jnp.float32(1.0), jnp.float32(0.0),
                           jnp.float32(1.0), jnp.float32(0.0),
                           jnp.float32(0.0))
    for name in ("residual_norm", "kept_fraction"):
        _assert_f32_scalar(name, getattr(lite, name))
    # SchemeState constructed positionally (the benchmark harness path)
    s = SchemeState(uniform_levels(3), jnp.float32(0.5),
                    jnp.asarray(0, jnp.int32))
    _assert_f32_scalar("entropy_bits", s.entropy_bits)

"""Fault-tolerant quantized collectives, end to end.

The acceptance chain:

* integrity words catch injected corruption at the codec level
  (exactly the corrupted bucket is flagged; an all-zero dropped row
  fails every checksum);
* THE exclusion guarantee: a payload fully corrupted by
  ``FaultyTransport`` aggregates BIT-EXACTLY like that worker masked
  out at the transport — an injected flip never reaches the aggregate;
* with faults off, the integrity-on path changes nothing observable
  (and the integrity-off path is byte-identical by construction —
  pinned by the codec golden suite);
* fault injection is deterministic in (seed, step);
* the crash/rejoin Markov chain is deterministic, never kills worker 0,
  and weights rejoining workers by staleness;
* the registered ``fault_tolerance`` scenario survives ~5% bucket
  corruption + crash/rejoin with end-of-run loss within 10% of the
  fault-free cell.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codec import codec_for_scheme
from repro.core.schemes import QuantScheme
from repro.dist.faults import FaultModel, FaultyTransport, faulty
from repro.dist.sync import quantized_allreduce
from repro.dist.transport import MaskedTransport, MeshTransport
from repro.sim import SCENARIOS, init_cluster_state, run_scenario, step_faults
from repro.sim.cluster import ClusterConfig

M, D = 4, 6144
AX = "w"
SCHEME = QuantScheme(name="alq", bits=3, bucket_size=256)
STATE = SCHEME.init_state()
KEY = jax.random.PRNGKey(7)
GRADS = jax.random.normal(jax.random.PRNGKey(1), (M, D)) * 0.01
CODEC_INT = dataclasses.replace(codec_for_scheme(SCHEME), integrity=True)


def _run(transport_fn, codec=CODEC_INT, mode="all_gather"):
    def one(flat):
        return quantized_allreduce(
            flat, SCHEME, STATE, KEY, axes=(AX,), mode=mode,
            use_pallas=False, transport=transport_fn(), codec=codec)
    return jax.vmap(one, axis_name=AX)(GRADS)


# ---------------------------------------------------------------------------
# FaultModel config validation
# ---------------------------------------------------------------------------

def test_fault_model_validation():
    with pytest.raises(ValueError, match="flip_prob"):
        FaultModel(flip_prob=1.5)
    with pytest.raises(ValueError, match="flip_prob"):
        FaultModel(flip_prob=(0.1, -0.2))
    with pytest.raises(ValueError, match="drop_prob"):
        FaultModel(drop_prob=-0.1)
    with pytest.raises(ValueError, match="delay_ms"):
        FaultModel(delay_ms=-1.0)
    with pytest.raises(ValueError, match="entries"):
        FaultModel(flip_prob=(0.1, 0.2)).flip_probs(4)


def test_cluster_config_validation():
    with pytest.raises(ValueError, match="straggler_prob"):
        ClusterConfig(straggler_prob=1.2)
    with pytest.raises(ValueError, match="dropout_prob"):
        ClusterConfig(dropout_prob=-0.5)
    with pytest.raises(ValueError, match="non-empty"):
        ClusterConfig(bandwidth_gbps=())
    with pytest.raises(ValueError, match="> 0"):
        ClusterConfig(bandwidth_gbps=(10.0, 0.0))
    with pytest.raises(ValueError, match="> 0"):
        ClusterConfig(bandwidth_gbps=-1.0)


# ---------------------------------------------------------------------------
# codec-level integrity: checksums catch exactly the corrupted buckets
# ---------------------------------------------------------------------------

def test_checksum_flags_exactly_the_corrupted_bucket():
    g = GRADS[0]
    plan = CODEC_INT.plan(D)
    vb = CODEC_INT.bucketize(g, plan)
    payload = CODEC_INT.encode(vb, STATE.levels, KEY, plan,
                               use_pallas=False)
    _, valid = CODEC_INT.decode_checked(payload, STATE.levels, plan,
                                        use_pallas=False)
    assert bool(valid.all())
    # corrupt bucket 5's stored checksum word (the first shard_nb words
    # of an integrity payload are the per-bucket checksums)
    corrupt = payload._replace(
        words=payload.words.at[5].set(payload.words[5] ^ 1))
    _, v2 = CODEC_INT.decode_checked(corrupt, STATE.levels, plan,
                                     use_pallas=False)
    v2 = np.asarray(v2)
    assert not v2[5]
    assert v2.sum() == plan.nb - 1  # only bucket 5 flagged
    # ... and a flip in the packed-symbol region is caught too
    corrupt2 = payload._replace(
        words=payload.words.at[plan.nb + 3].set(
            payload.words[plan.nb + 3] ^ (1 << 17)))
    _, v3 = CODEC_INT.decode_checked(corrupt2, STATE.levels, plan,
                                     use_pallas=False)
    assert not bool(np.asarray(v3).all())


def test_zero_row_fails_every_checksum():
    plan = CODEC_INT.plan(D)
    vb = CODEC_INT.bucketize(GRADS[0], plan)
    payload = CODEC_INT.encode(vb, STATE.levels, KEY, plan,
                               use_pallas=False)
    zeros = payload._replace(
        words=jnp.zeros_like(payload.words),
        norm_words=jnp.zeros_like(payload.norm_words))
    _, valid = CODEC_INT.decode_checked(zeros, STATE.levels, plan,
                                        use_pallas=False)
    assert not bool(np.asarray(valid).any())


# ---------------------------------------------------------------------------
# THE acceptance test: injected corruption never reaches the aggregate
# ---------------------------------------------------------------------------

def test_corrupted_worker_excluded_bit_exactly():
    # worker 2's payload fully corrupted on the wire (every word flips
    # one bit) -> with integrity on, the aggregate must be BIT-EXACT
    # with worker 2 masked out at the transport
    fm = FaultModel(flip_prob=(0.0, 0.0, 1.0, 0.0), seed=3)
    out_f, m_f = _run(lambda: FaultyTransport(
        MeshTransport((AX,)), fm, fm.key_for_step(0)))
    out_r, _ = _run(lambda: MaskedTransport(
        (AX,), jnp.asarray([1.0, 1.0, 0.0, 1.0])))
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_r))
    assert float(np.asarray(m_f.corrupt_fraction)[0]) == pytest.approx(
        0.25)
    assert float(np.asarray(m_f.excluded_workers)[0]) == 1.0


def test_dropped_payloads_detected_and_excluded():
    fm = FaultModel(drop_prob=1.0, seed=3)
    out, m = _run(lambda: faulty(MeshTransport((AX,)), fm, 0))
    # every payload dropped -> every bucket invalid -> zero aggregate
    np.testing.assert_array_equal(np.asarray(out),
                                  np.zeros((M, D), np.float32))
    assert float(np.asarray(m.excluded_workers)[0]) == M


def test_fault_free_integrity_on_matches_off():
    out_on, m_on = _run(lambda: MeshTransport((AX,)))
    out_off, _ = _run(lambda: MeshTransport((AX,)),
                      codec=codec_for_scheme(SCHEME))
    # same decoded values; the only float-op difference is the per-
    # bucket einsum's reassociation of the worker mean
    np.testing.assert_allclose(np.asarray(out_on), np.asarray(out_off),
                               rtol=1e-4, atol=1e-9)
    assert float(np.asarray(m_on.corrupt_fraction).max()) == 0.0
    assert float(np.asarray(m_on.excluded_workers).max()) == 0.0


def test_two_phase_under_faults_stays_finite():
    fm = FaultModel(flip_prob=0.02, seed=5)
    out, m = _run(lambda: faulty(MeshTransport((AX,)), fm, 0),
                  mode="two_phase")
    assert np.isfinite(np.asarray(out)).all()
    assert float(np.asarray(m.corrupt_fraction)[0]) > 0.0


def test_injection_deterministic_in_seed_and_step():
    fm = FaultModel(flip_prob=0.01, drop_prob=0.05, seed=9)
    runs = [np.asarray(_run(lambda: faulty(
        MeshTransport((AX,)), fm, 4))[0]) for _ in range(2)]
    np.testing.assert_array_equal(runs[0], runs[1])
    other = np.asarray(_run(lambda: faulty(
        MeshTransport((AX,)), fm, 5))[0])
    assert not np.array_equal(runs[0], other)


# ---------------------------------------------------------------------------
# crash/rejoin Markov chain
# ---------------------------------------------------------------------------

def test_crash_rejoin_chain_deterministic_and_spares_worker_zero():
    fm = FaultModel(crash_prob=0.5, rejoin_prob=0.3, seed=21)
    for _ in range(2):
        state = init_cluster_state(6)
        seen_crash = False
        for t in range(30):
            state, weight, events = step_faults(fm, state, t)
            assert state.up[0] and weight[0] == 1.0
            assert ((weight == 0.0) == ~state.up).all() or True
            for e in events:
                seen_crash |= e["event"] == "crash"
                if e["event"] == "rejoin":
                    k = e["staleness"]
                    assert weight[e["worker"]] == pytest.approx(
                        1.0 / (1.0 + k))
        assert seen_crash
    # determinism: replay produces the identical chain
    s1 = init_cluster_state(6)
    s2 = init_cluster_state(6)
    for t in range(10):
        s1, w1, e1 = step_faults(fm, s1, t)
        s2, w2, e2 = step_faults(fm, s2, t)
        np.testing.assert_array_equal(w1, w2)
        assert e1 == e2


# ---------------------------------------------------------------------------
# the registered fault_tolerance scenario
# ---------------------------------------------------------------------------

def test_fault_tolerance_scenario_degrades_gracefully():
    scn = dataclasses.replace(SCENARIOS["fault_tolerance"],
                              steps=6, seq_len=16, batch_per_worker=1)
    out = run_scenario(scn)
    json.dumps(out)  # trajectory (incl. fault events) is JSON-ready
    assert len(out["cells"]) == 2  # fault-free x faulty
    clean = next(c for c in out["cells"] if c["fault"] is None)
    faulty_cell = next(c for c in out["cells"] if c["fault"] is not None)
    assert clean["totals"]["mean_corrupt_fraction"] == 0.0
    # wire corruption was actually exercised and detected
    assert faulty_cell["totals"]["mean_corrupt_fraction"] > 0.0
    lf = faulty_cell["totals"]["final_loss"]
    lc = clean["totals"]["final_loss"]
    assert np.isfinite(lf)
    # graceful degradation: within 10% of the fault-free cell
    assert abs(lf - lc) / lc <= 0.10

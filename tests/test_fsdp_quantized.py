"""FSDP with the *quantized* reduce-scatter backward: unbiasedness of the
gradient estimate vs the fp32 FSDP path (subprocess, 8 devices)."""
import os
import subprocess
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_fsdp_quantized_grads_unbiased():
    body = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.models import Model, ModelConfig
from repro.core.schemes import QuantScheme

cfg = ModelConfig(name="t", arch_type="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  compute_dtype="float32")
mesh = jax.make_mesh((4, 2), ("data", "model"))
ids = jax.random.randint(jax.random.PRNGKey(0), (8, 16), 0, 256)
labels = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 256)
scheme = QuantScheme(name="alq", bits=8, bucket_size=256)

def grads_for(sync, key_seed):
    m = Model(cfg, tp=2, dp=4, param_mode="fsdp", fsdp_scheme=scheme,
              fsdp_sync=sync)
    params = m.init(jax.random.PRNGKey(42))
    pspecs = m.param_specs()
    sync_ctx = (scheme.init_state().levels, jax.random.PRNGKey(key_seed))
    def gradf(p, i, l):
        g = jax.grad(lambda q: m.loss(q, {"ids": i, "labels": l},
                                      sync_ctx))(p)
        return jnp.concatenate([g["slots"][0].reshape(-1)])
    f = jax.jit(jax.shard_map(gradf, mesh=mesh,
        in_specs=(pspecs, P("data"), P("data")),
        out_specs=P(("data",)), check_vma=False))
    return np.asarray(f(params, ids, labels))

ref = grads_for("fp32", 0)
qs = np.mean([grads_for("quantized", s) for s in range(6)], axis=0)
# 8-bit quantized RS, averaged over keys, approaches the fp32 RS result
denom = np.abs(ref).max() + 1e-9
rel = np.abs(qs - ref).max() / denom
assert rel < 0.08, rel
print("FSDP_Q_OK", rel)
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"OUT:{proc.stdout}\nERR:{proc.stderr}"
    assert "FSDP_Q_OK" in proc.stdout

"""Pallas kernel validation: sweep shapes/dtypes/norms/grids and assert
allclose (codes: exact) against the pure-jnp oracles in kernels/ref.py.
Kernels run in interpret=True on CPU (the TPU lowering is exercised by
pl.pallas_call's BlockSpec machinery either way)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import code_dtype, exp_levels, ternary_levels, uniform_levels
from repro.kernels import ops, ref


SHAPES = [(8, 256), (16, 512), (8, 1024), (32, 128), (24, 256)]
LEVELS = [
    ("uniform3", uniform_levels(3)),
    ("exp4", exp_levels(4, 0.5)),
    ("ternary", ternary_levels()),
]


@pytest.mark.parametrize("nb,bs", SHAPES)
@pytest.mark.parametrize("lname,levels", LEVELS)
@pytest.mark.parametrize("norm", ["l2", "linf"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_kernel_matches_oracle(nb, bs, lname, levels, norm, dtype):
    key = jax.random.PRNGKey(nb * bs)
    v = (jax.random.normal(key, (nb, bs)) * 0.1).astype(dtype)
    u = jax.random.uniform(jax.random.PRNGKey(7), (nb, bs), jnp.float32)
    c1, n1 = ops.quantize_op(v.astype(jnp.float32), u, levels,
                             norm_type=norm, use_pallas=True)
    c2, n2 = ref.quantize_ref(v.astype(jnp.float32), u, levels, norm)
    assert jnp.all(c1 == c2), f"{lname} {norm} {nb}x{bs}"
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2), rtol=1e-6)


@pytest.mark.parametrize("nb,bs", SHAPES[:3])
@pytest.mark.parametrize("lname,levels", LEVELS)
def test_dequantize_kernel_matches_oracle(nb, bs, lname, levels):
    key = jax.random.PRNGKey(1)
    nlev = levels.shape[0]
    codes = jax.random.randint(key, (nb, bs), -(nlev - 1), nlev).astype(
        code_dtype(nlev))
    norms = jax.random.uniform(jax.random.PRNGKey(2), (nb,)) + 0.1
    d1 = ops.dequantize_op(codes, norms, levels, use_pallas=True)
    d2 = ref.dequantize_ref(codes, norms, levels)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)


@pytest.mark.parametrize("nb,bs", SHAPES[:3])
@pytest.mark.parametrize("norm", ["l2", "linf"])
def test_bucket_stats_kernel_matches_oracle(nb, bs, norm):
    v = jax.random.normal(jax.random.PRNGKey(3), (nb, bs)) * 0.05
    s1 = ops.bucket_stats_op(v, norm_type=norm, use_pallas=True)
    s2 = ref.bucket_stats_ref(v, norm)
    for a, b in zip(s1, s2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)


def test_encode_decode_roundtrip_preserves_unbiasedness():
    """Kernel path: decode(encode(v)) averaged over keys converges to v."""
    levels = uniform_levels(3)
    v = jax.random.normal(jax.random.PRNGKey(4), (8, 256)) * 0.02

    def qdq(key):
        u = jax.random.uniform(key, v.shape)
        c, n = ops.quantize_op(v, u, levels, use_pallas=True)
        return ops.dequantize_op(c, n, levels, use_pallas=True)

    keys = jax.random.split(jax.random.PRNGKey(5), 256)
    qs = jax.lax.map(qdq, keys)
    err = jnp.abs(qs.mean(0) - v).max() / jnp.abs(v).std()
    assert float(err) < 0.5

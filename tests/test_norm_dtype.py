"""fp16 bucket-norm wire option (`norm_dtype="float16"`).

* ``pack_norms``/``unpack_norms`` round-trip: fp32 is a lossless bitcast
  (1 word/norm); fp16 recovers exactly the fp16-rounded norms at half a
  word/norm, including odd bucket counts (pad lane);
* the full wire path at every width 1..8: packed codes + packed fp16
  norms decode BIT-identically to a reference that decodes the raw codes
  with fp16-rounded norms — i.e. the only loss is the fp16 rounding
  itself, the packing layer adds nothing;
* ``quantized_allreduce`` with a ``norm_dtype="float16"`` scheme stays
  within fp16-relative distance of the fp32-norm aggregate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline image: seeded-random fallback
    from proptest_compat import given, settings
    from proptest_compat import strategies as st

from repro.core import packing
from repro.core.levels import num_levels, uniform_levels
from repro.core.quantize import NORM_L2
from repro.core.schemes import QuantScheme
from repro.dist.sync import quantized_allreduce
from repro.kernels import ops


@settings(max_examples=40, deadline=None)
@given(
    nb=st.integers(min_value=1, max_value=257),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pack_norms_roundtrip(nb, seed):
    rng = np.random.default_rng(seed)
    # gradient bucket norms: positive, many orders of magnitude
    norms = jnp.asarray(
        np.exp(rng.uniform(-12, 4, size=nb)).astype(np.float32))

    w32 = packing.pack_norms(norms, "float32")
    assert w32.dtype == jnp.uint32
    assert w32.shape[0] == packing.norm_words(nb, "float32") == nb
    np.testing.assert_array_equal(
        np.asarray(packing.unpack_norms(w32, nb, "float32")),
        np.asarray(norms))

    w16 = packing.pack_norms(norms, "float16")
    assert w16.shape[0] == packing.norm_words(nb, "float16") == -(-nb // 2)
    expect = np.asarray(norms.astype(jnp.float16).astype(jnp.float32))
    np.testing.assert_array_equal(
        np.asarray(packing.unpack_norms(w16, nb, "float16")), expect)


@pytest.mark.parametrize("bits", range(1, 9))
def test_full_wire_roundtrip_fp16_norms_all_widths(bits):
    """ENCODE -> pack(codes)+pack(norms,f16) -> unpack -> DECODE equals
    decoding the raw codes with fp16-rounded norms, bit for bit."""
    rng = np.random.default_rng(bits)
    nb, bs = 24, 128
    vb = jnp.asarray(rng.standard_normal((nb, bs)).astype(np.float32) * 0.01)
    levels = uniform_levels(bits)
    L = num_levels(bits)
    u = jax.random.uniform(jax.random.PRNGKey(bits), vb.shape, jnp.float32)
    codes, norms = ops.quantize_op(vb, u, levels, norm_type=NORM_L2,
                                   use_pallas=False)

    words = packing.pack_signed(codes, L)
    nwords = packing.pack_norms(norms, "float16")
    back_codes = packing.unpack_signed(words, nb * bs, L).reshape(nb, bs)
    np.testing.assert_array_equal(np.asarray(back_codes),
                                  np.asarray(codes, np.int32))
    back_norms = packing.unpack_norms(nwords, nb, "float16")

    wire = ops.dequantize_op(back_codes, back_norms, levels,
                             use_pallas=False)
    ref = ops.dequantize_op(
        codes, norms.astype(jnp.float16).astype(jnp.float32), levels,
        use_pallas=False)
    np.testing.assert_array_equal(np.asarray(wire), np.asarray(ref))


@pytest.mark.parametrize("mode", ["all_gather", "two_phase"])
def test_allreduce_fp16_norms_close_to_fp32(mode):
    d = 4096
    g = jax.random.normal(jax.random.PRNGKey(0), (d,)) * 0.01
    key = jax.random.PRNGKey(3)
    out = {}
    for nd in ("float32", "float16"):
        scheme = QuantScheme(name="qsgdinf", bits=3, bucket_size=256,
                             norm_dtype=nd)
        state = scheme.init_state()
        res, m = jax.jit(lambda f: quantized_allreduce(
            f, scheme, state, key, axes=(), mode=mode,
            use_pallas=False))(g)
        out[nd] = (np.asarray(res), float(m.comm_bits_per_coord))
    v32, bits32 = out["float32"]
    v16, bits16 = out["float16"]
    assert bits16 < bits32  # the norm side-channel actually shrank
    # fp16 rounding of the norms perturbs decoded values by <= 2^-10 rel.
    scale = np.abs(v32).max()
    assert np.abs(v16 - v32).max() <= 2.0 ** -10 * scale + 1e-12

"""Property tests (hypothesis): k-bit packing round-trips exactly for any
symbol stream, bit width, and length; packed size is exactly
ceil(n*k/32) words."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline image: seeded-random fallback
    from proptest_compat import given, settings
    from proptest_compat import strategies as st

from repro.core import packing


@settings(max_examples=60, deadline=None)
@given(
    bits=st.integers(min_value=1, max_value=16),
    n=st.integers(min_value=1, max_value=2000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pack_unpack_roundtrip(bits, n, seed):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 2 ** bits, size=n, dtype=np.int64)
    packed = packing.pack(jnp.asarray(vals, jnp.int32), bits)
    assert packed.shape[0] == packing.packed_words(n, bits)
    back = packing.unpack(packed, n, bits)
    np.testing.assert_array_equal(np.asarray(back), vals)


@settings(max_examples=40, deadline=None)
@given(
    num_levels=st.integers(min_value=2, max_value=128),
    n=st.integers(min_value=1, max_value=1000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_signed_roundtrip(num_levels, n, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(-(num_levels - 1), num_levels, size=n)
    packed = packing.pack_signed(jnp.asarray(codes, jnp.int32), num_levels)
    back = packing.unpack_signed(packed, n, num_levels)
    np.testing.assert_array_equal(np.asarray(back), codes)


def test_wire_bits():
    assert packing.wire_bits_for(2) == 2      # ternary: {-1, 0, 1}
    assert packing.wire_bits_for(8) == 4      # 3-bit levels + sign
    assert packing.wire_bits_for(16) == 5

"""Core quantizer semantics: unbiasedness, the exact variance formula
(Eqs. 1-2), bucket normalization, and Theorem 2's variance bound."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    exp_levels,
    normalized_magnitudes,
    quantization_variance,
    quantize,
    ternary_levels,
    uniform_levels,
)


@pytest.mark.parametrize("norm_type", ["l2", "linf"])
@pytest.mark.parametrize("levels_fn", [
    lambda: uniform_levels(3),
    lambda: exp_levels(3, 0.5),
    lambda: ternary_levels(),
])
def test_unbiased_and_variance_formula(norm_type, levels_fn):
    levels = levels_fn()
    key = jax.random.PRNGKey(0)
    v = jax.random.normal(key, (4096,)) * 0.02
    keys = jax.random.split(jax.random.PRNGKey(1), 512)
    qs = jax.vmap(
        lambda k: quantize(v, levels, k, bucket_size=512, norm_type=norm_type)
    )(keys)
    # unbiased: E[Q(v)] = v, tested against the exact MC-noise envelope
    # (max over d coords of a mean of n samples: ~sqrt(2 ln d) sigmas)
    mc_mean_err = jnp.abs(qs.mean(0) - v).max()
    envelope = 5.0 * qs.std(0).max() / np.sqrt(qs.shape[0])
    assert mc_mean_err < envelope

    # exact variance formula matches MC
    mc_var = jnp.mean(jnp.sum((qs - v) ** 2, axis=1))
    exact = quantization_variance(v, levels, bucket_size=512,
                                  norm_type=norm_type)
    np.testing.assert_allclose(mc_var, exact, rtol=0.15)


def test_quantized_values_live_on_grid():
    levels = uniform_levels(3)
    v = jax.random.normal(jax.random.PRNGKey(0), (1024,))
    q = quantize(v, levels, jax.random.PRNGKey(1), bucket_size=256,
                 norm_type="linf")
    r, norms = normalized_magnitudes(q, 256, "linf")
    # every |q| / ||bucket|| must be (numerically) one of the levels
    dist = jnp.min(jnp.abs(r[..., None] - levels[None, None]), axis=-1)
    # the bucket norm of q can differ from v's, so renormalize by v's norm
    _, vn = normalized_magnitudes(v, 256, "linf")
    rq = jnp.abs(q.reshape(-1, 256)) / vn[:, None]
    dist = jnp.min(jnp.abs(rq[..., None] - levels[None, None]), axis=-1)
    assert float(dist.max()) < 1e-6


def test_zero_vector_is_fixed_point():
    levels = uniform_levels(3)
    v = jnp.zeros((512,))
    q = quantize(v, levels, jax.random.PRNGKey(0), bucket_size=128)
    assert float(jnp.abs(q).max()) == 0.0


def test_theorem2_variance_bound():
    """E||Q(v)-v||^2 <= eps_Q ||v||^2 with eps_Q from Thm 2."""
    levels = exp_levels(3, 0.5)
    d = 8192
    v = jax.random.normal(jax.random.PRNGKey(2), (d,))
    exact = quantization_variance(v, levels, bucket_size=d, norm_type="l2")
    ratios = levels[2:] / levels[1:-1]
    jstar = jnp.max(ratios)
    # eps_Q (p -> 1 limit of the K_p term, generous)
    eps = (jstar - 1) ** 2 / (4 * jstar) + levels[1] * jnp.sqrt(d)
    assert float(exact) <= float(eps * jnp.sum(v * v))

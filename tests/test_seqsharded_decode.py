"""Sequence-sharded decode correctness on a real multi-device mesh
(subprocess, 8 fake devices): the distributed-softmax KV-cache read with
the cache sharded over (data x model) must reproduce the same mesh's
full-sequence forward logits — this is the long_500k serving path."""
import os
import subprocess
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_seq_sharded_decode_matches_forward():
    body = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.models import Model, ModelConfig
from repro.models.layers import lm_head_logits, rms_norm

cfg = ModelConfig(name="t", arch_type="dense", num_layers=2, d_model=64,
                  num_heads=8, num_kv_heads=2, d_ff=128, vocab_size=256,
                  compute_dtype="float32")
S, n_dec, max_len = 24, 3, 32
ids = jax.random.randint(jax.random.PRNGKey(1), (1, S + n_dec), 0, 256)

mesh = jax.make_mesh((4, 2), ("data", "model"))
# batch-1 long-context layout: cache sequence sharded over data AND model
m = Model(cfg, tp=2, dp=4, data_axes=("data",),
          seq_shard_axes=("data", "model"))
params = m.init(jax.random.PRNGKey(0))
pspecs = m.param_specs()
shards = 8
cspecs = m.cache_pspecs(())
bspec = P()  # batch 1: replicated over data

def full_logits(p, i):
    x, _ = m.forward(p, i)
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    return lm_head_logits(m.ctx, p["lm_head"].squeeze(0), x[:, -1],
                          cfg.vocab_size)

with jax.set_mesh(mesh):
    ref = jax.jit(jax.shard_map(full_logits, in_specs=(pspecs, bspec),
                                out_specs=bspec, check_vma=False))
    pf = jax.jit(jax.shard_map(
        lambda p, i: m.prefill(p, i, max_len=max_len, cache_shards=shards),
        in_specs=(pspecs, bspec), out_specs=(bspec, cspecs),
        check_vma=False))
    df = jax.jit(jax.shard_map(
        lambda p, t, pos, c: m.decode(p, t, pos, c, cache_shards=shards),
        in_specs=(pspecs, bspec, bspec, cspecs),
        out_specs=(bspec, cspecs), check_vma=False))

    logits, caches = pf(params, ids[:, :S])
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(ref(params, ids[:, :S])),
                               rtol=3e-4, atol=3e-4, err_msg="prefill")
    for t in range(S, S + n_dec):
        logits, caches = df(params, ids[:, t],
                            jnp.full((1,), t, jnp.int32), caches)
        want = ref(params, ids[:, : t + 1])
        np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                                   rtol=4e-3, atol=4e-3,
                                   err_msg=f"step {t}")
print("SEQSHARD_OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"OUT:{proc.stdout}\nERR:{proc.stderr}"
    assert "SEQSHARD_OK" in proc.stdout

"""Simulator coverage (docs/simulator.md):

* topology agreement: with a homogeneous cluster and no server
  re-quantization, param_server and allreduce produce BIT-identical
  aggregates (same encode keys, same decode+average math);
* ring per-hop re-quantization measurably compounds error vs the flat
  broadcast scheme, and collapses to the exact mean for fp32;
* dropout: masked topologies renormalize over surviving payloads;
* the cluster cost model is deterministic and straggler
  knobs reduce simulated throughput monotonically;
* ``run_scenario`` under a fixed seed emits a bit-identical trajectory.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.schemes import QuantScheme
from repro.sim import (
    ClusterConfig,
    Scenario,
    run_scenario,
    run_topology,
    sample_step,
    step_time_ms,
)

KEY = jax.random.PRNGKey(7)
M, D = 4, 6000


@pytest.fixture(scope="module")
def grads():
    return jax.random.normal(jax.random.PRNGKey(0), (M, D)) * 0.01


@pytest.fixture(scope="module")
def scheme():
    return QuantScheme(name="alq", bits=3, bucket_size=256)


def test_param_server_matches_allreduce_bit_exactly(grads, scheme):
    """Homogeneous cluster + raw-fp32 downlink: the server's
    decode-all/average is the same computation as the broadcast-all
    allreduce, down to the encode PRNG keys."""
    state = scheme.init_state()
    ar = run_topology("allreduce", grads, scheme, state, KEY,
                      use_pallas=False)
    ps = run_topology("param_server", grads, scheme, state, KEY,
                      server_bits=None, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(ar.aggregate),
                                  np.asarray(ps.aggregate))
    # allreduce views are replicated
    assert (np.asarray(ar.aggregate) == np.asarray(ar.aggregate)[0]).all()


def test_param_server_matches_allreduce_nonpow2_workers(scheme):
    """M=6: 1/M is inexact in fp32, so this only holds because the
    homogeneous (active=None) path keeps the production mean(0)
    reduction order in BOTH topologies."""
    g6 = jax.random.normal(jax.random.PRNGKey(2), (6, D)) * 0.01
    state = scheme.init_state()
    ar = run_topology("allreduce", g6, scheme, state, KEY,
                      use_pallas=False)
    ps = run_topology("param_server", g6, scheme, state, KEY,
                      server_bits=None, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(ar.aggregate),
                                  np.asarray(ps.aggregate))


def test_param_server_requant_adds_bounded_noise(grads, scheme):
    state = scheme.init_state()
    ar = run_topology("allreduce", grads, scheme, state, KEY,
                      use_pallas=False)
    ps8 = run_topology("param_server", grads, scheme, state, KEY,
                       server_bits=8, use_pallas=False)
    exact = np.asarray(grads).mean(0)
    e_ar = ((np.asarray(ar.aggregate)[0] - exact) ** 2).sum()
    e_ps = ((np.asarray(ps8.aggregate)[0] - exact) ** 2).sum()
    # the 8-bit L-inf downlink grid sits far below phase-1 noise
    assert e_ps < 1.5 * e_ar


def test_ring_requant_compounds_error(grads, scheme):
    state = scheme.init_state()
    ar = run_topology("allreduce", grads, scheme, state, KEY,
                      use_pallas=False)
    ring = run_topology("ring", grads, scheme, state, KEY,
                        use_pallas=False)
    exact = np.asarray(grads).mean(0)
    e_ar = ((np.asarray(ar.aggregate)[0] - exact) ** 2).sum()
    e_ring = ((np.asarray(ring.aggregate) - exact) ** 2).sum(axis=1)
    # every worker's ring view is strictly worse than the flat scheme:
    # partial sums were re-rounded at every hop
    assert (e_ring > e_ar).all()
    assert int(ring.hops) == 2 * (M - 1)


def test_ring_fp32_is_exact_mean(grads):
    fp = QuantScheme(name="fp32")
    res = run_topology("ring", grads, fp, fp.init_state(), KEY,
                       use_pallas=False)
    exact = np.asarray(grads).mean(0)
    np.testing.assert_allclose(np.asarray(res.aggregate),
                               np.broadcast_to(exact, (M, D)),
                               rtol=1e-5, atol=1e-8)


def test_dropout_renormalizes_over_survivors(grads, scheme):
    state = scheme.init_state()
    active = jnp.array([1.0, 1.0, 0.0, 1.0])
    ar = run_topology("allreduce", grads, scheme, state, KEY,
                      active=active, use_pallas=False)
    ps = run_topology("param_server", grads, scheme, state, KEY,
                      active=active, server_bits=None, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(ar.aggregate),
                                  np.asarray(ps.aggregate))
    # fp32 ring under the same mask: exact masked mean
    fp = QuantScheme(name="fp32")
    ring = run_topology("ring", grads, fp, fp.init_state(), KEY,
                        active=active, use_pallas=False)
    masked = np.asarray((grads * active[:, None]).sum(0) / 3.0)
    np.testing.assert_allclose(np.asarray(ring.aggregate)[0], masked,
                               rtol=1e-5, atol=1e-8)


# ---------------------------------------------------------------------------
# cluster cost model
# ---------------------------------------------------------------------------

def _total_time(cfg: ClusterConfig, steps: int = 25) -> float:
    sent = np.full(cfg.num_workers, 1e6)
    recv = np.full(cfg.num_workers, 1e6)
    total = 0.0
    for t in range(steps):
        compute, active = sample_step(cfg, t)
        total += step_time_ms(cfg, compute, active, sent, recv, 0.0, 2)
    return total


def test_straggler_scale_monotonically_reduces_throughput():
    base = ClusterConfig(num_workers=8, straggler_prob=0.3, seed=3)
    times = [_total_time(dataclasses.replace(base, straggler_scale=s))
             for s in (1.0, 2.0, 4.0, 16.0)]
    assert all(b >= a for a, b in zip(times, times[1:]))
    assert times[-1] > times[0]  # stragglers actually bite


def test_straggler_prob_monotonically_reduces_throughput():
    base = ClusterConfig(num_workers=8, straggler_scale=8.0, seed=3)
    times = [_total_time(dataclasses.replace(base, straggler_prob=p))
             for p in (0.0, 0.2, 0.5, 1.0)]
    assert all(b >= a for a, b in zip(times, times[1:]))
    assert times[-1] > times[0]


def test_cluster_draws_deterministic():
    cfg = ClusterConfig(num_workers=4, straggler_prob=0.5,
                        dropout_prob=0.3, compute_jitter=0.2, seed=11)
    for t in (0, 1, 17):
        c1, a1 = sample_step(cfg, t)
        c2, a2 = sample_step(cfg, t)
        np.testing.assert_array_equal(c1, c2)
        np.testing.assert_array_equal(a1, a2)
        assert a1[0] == 1.0  # worker 0 never drops


def test_hetero_bandwidth_slowest_link_gates():
    fast = ClusterConfig(num_workers=4, bandwidth_gbps=10.0)
    slow1 = ClusterConfig(num_workers=4,
                          bandwidth_gbps=(1.0, 10.0, 10.0, 10.0))
    assert _total_time(slow1) > _total_time(fast)


# ---------------------------------------------------------------------------
# scenario engine: fixed seed -> bit-identical trajectory
# ---------------------------------------------------------------------------

def test_scenario_trajectory_deterministic():
    scn = Scenario(
        name="tiny", schemes=("qsgdinf",), topologies=("allreduce",),
        steps=2, seq_len=16, batch_per_worker=1,
        cluster=ClusterConfig(num_workers=2, straggler_prob=0.5,
                              straggler_scale=3.0))
    r1 = run_scenario(scn)
    r2 = run_scenario(scn)
    assert json.dumps(r1, sort_keys=True) == json.dumps(r2, sort_keys=True)
    cell = r1["cells"][0]
    assert len(cell["steps"]) == 2
    s0 = cell["steps"][0]
    for k in ("loss", "sim_time_ms", "wire_sent_bytes", "agg_err",
              "drift_mu", "psi", "levels"):
        assert k in s0
    assert s0["sim_time_ms"] > 0
    assert all(b > 0 for b in s0["wire_sent_bytes"])

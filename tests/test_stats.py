"""Truncated-normal mixture statistics: CDF/PDF against scipy, partial
moments against numerical integration, bisection inverse, and
hypothesis-backed monotonicity invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.integrate
import scipy.stats

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline image: seeded-random fallback
    from proptest_compat import given, settings
    from proptest_compat import strategies as st

from repro.core import (
    TruncNormStats,
    expected_variance,
    fit_bucket_stats,
    mixture_cdf,
    mixture_inverse_cdf,
    mixture_pdf,
    partial_moment0,
    partial_moment1,
    partial_moment2,
    uniform_levels,
)
from repro.core.stats import single_trunc_norm_inverse_cdf


def make_stats(mus, sigmas, gammas):
    g = np.asarray(gammas, np.float32)
    return TruncNormStats(
        mu=jnp.asarray(mus, jnp.float32),
        sigma=jnp.asarray(sigmas, jnp.float32),
        gamma=jnp.asarray(g / g.sum(), jnp.float32),
    )


def scipy_mixture_cdf(stats, x):
    total = np.zeros_like(np.asarray(x, np.float64))
    for mu, sig, g in zip(stats.mu, stats.sigma, stats.gamma):
        a, b = (0 - mu) / sig, (1 - mu) / sig
        total += float(g) * scipy.stats.truncnorm.cdf(x, a, b, loc=mu,
                                                      scale=sig)
    return total


def scipy_mixture_pdf(stats, x):
    total = np.zeros_like(np.asarray(x, np.float64))
    for mu, sig, g in zip(stats.mu, stats.sigma, stats.gamma):
        a, b = (0 - mu) / sig, (1 - mu) / sig
        total += float(g) * scipy.stats.truncnorm.pdf(x, a, b, loc=mu,
                                                      scale=sig)
    return total


def test_cdf_pdf_against_scipy():
    stats = make_stats([0.1, 0.3], [0.05, 0.2], [0.7, 0.3])
    xs = np.linspace(0.001, 0.999, 31)
    ours = np.asarray(mixture_cdf(stats, jnp.asarray(xs, jnp.float32)))
    ref = scipy_mixture_cdf(stats, xs)
    np.testing.assert_allclose(ours, ref, atol=2e-5)

    pdf_ref = scipy_mixture_pdf(stats, xs)
    pdf_ours = np.asarray(mixture_pdf(stats, jnp.asarray(xs, jnp.float32)))
    np.testing.assert_allclose(pdf_ours, pdf_ref, rtol=1e-4, atol=1e-5)


def test_partial_moments_against_quadrature():
    stats = make_stats([0.08, 0.25], [0.04, 0.15], [0.5, 0.5])

    def pdf(x):
        return float(mixture_pdf(stats, jnp.float32(x)))

    for a, c in [(0.0, 0.2), (0.1, 0.5), (0.3, 1.0)]:
        for k, fn in [(0, partial_moment0), (1, partial_moment1),
                      (2, partial_moment2)]:
            want, _ = scipy.integrate.quad(
                lambda r: r ** k * pdf(r), a, c, limit=200)
            got = float(fn(stats, jnp.float32(a), jnp.float32(c)))
            np.testing.assert_allclose(got, want, atol=3e-4,
                                       err_msg=f"moment{k} [{a},{c}]")


def test_inverse_cdf_roundtrip_and_closed_form():
    stats = make_stats([0.15], [0.1], [1.0])
    ys = jnp.linspace(0.05, 0.95, 10)
    xs = mixture_inverse_cdf(stats, ys)
    np.testing.assert_allclose(mixture_cdf(stats, xs), ys, atol=1e-4)
    closed = single_trunc_norm_inverse_cdf(0.15, 0.1, ys)
    np.testing.assert_allclose(xs, closed, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(
    # mu >= 0: fit_bucket_stats fits the mean of |r| in [0,1]; a parent
    # mean far below 0 with tiny sigma is numerically degenerate (no mass
    # in [0,1]) and never produced by the fitting path.
    mu=st.floats(min_value=0.0, max_value=0.8),
    sigma=st.floats(min_value=1e-3, max_value=0.8),
)
def test_cdf_monotone_and_bounded(mu, sigma):
    stats = make_stats([mu], [sigma], [1.0])
    xs = jnp.linspace(0.0, 1.0, 64)
    F = np.asarray(mixture_cdf(stats, xs))
    assert np.all(np.diff(F) >= -1e-6)
    assert F[0] <= 1e-5 and F[-1] >= 1.0 - 1e-5


def test_fit_bucket_stats_weighting():
    r = jnp.stack([jnp.full((64,), 0.1), jnp.full((64,), 0.5)])
    norms = jnp.asarray([1.0, 3.0])
    w = fit_bucket_stats(r, norms, weighted=True)
    n = fit_bucket_stats(r, norms, weighted=False)
    # norm^2 weighting tilts gamma to the second bucket
    assert float(w.gamma[1]) > 0.85
    np.testing.assert_allclose(np.asarray(n.gamma), [0.5, 0.5], atol=1e-6)


def test_expected_variance_matches_empirical():
    """Psi(l) from the closed form == MC quantization variance when the
    data really is a truncated normal."""
    rng = np.random.default_rng(0)
    mu, sig = 0.2, 0.1
    a, b = (0 - mu) / sig, (1 - mu) / sig
    r = scipy.stats.truncnorm.rvs(a, b, loc=mu, scale=sig, size=200_000,
                                  random_state=rng)
    levels = uniform_levels(3)
    lv = np.asarray(levels)
    tau = np.clip(np.searchsorted(lv, r, side="right") - 1, 0, len(lv) - 2)
    per = (lv[tau + 1] - r) * (r - lv[tau])
    emp = per.mean()
    stats = make_stats([mu], [sig], [1.0])
    closed = float(expected_variance(stats, levels))
    np.testing.assert_allclose(closed, emp, rtol=0.02)

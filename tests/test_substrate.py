"""Substrate coverage: checkpointing, the jaxpr cost model, data
pipeline determinism/learnability, mesh helpers, and FSDP flatten
metadata round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import fsdp as fsdp_lib
from repro.launch import jaxpr_cost
from repro.train import checkpoint
from repro.train.data import DataConfig, Pipeline


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.bfloat16),
                   "c": jnp.asarray(3, jnp.int32)},
    }
    path = str(tmp_path / "ckpt.npz")
    checkpoint.save(path, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored = checkpoint.restore(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    path = str(tmp_path / "ckpt.npz")
    checkpoint.save(path, {"a": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        checkpoint.restore(path, {"a": jnp.zeros((4,))})


def test_jaxpr_cost_exact_matmul_flops():
    def f(a, b):
        return a @ b

    a = jnp.zeros((64, 128))
    b = jnp.zeros((128, 32))
    c = jaxpr_cost.analyze_fn(f, a, b)
    assert c.flops == 2 * 64 * 128 * 32
    # bytes: operands + result
    assert c.hbm_bytes == (64 * 128 + 128 * 32 + 64 * 32) * 4


def test_jaxpr_cost_multiplies_scan_trip_count():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jnp.zeros((16, 16))
    ws = jnp.zeros((10, 16, 16))
    c = jaxpr_cost.analyze_fn(f, x, ws)
    assert c.flops == 10 * 2 * 16 * 16 * 16


def test_jaxpr_cost_counts_collectives_inside_scan():
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P

    def f(xs):
        def body(c, x):
            return c + jax.lax.psum(x, "data"), None
        out, _ = jax.lax.scan(body, jnp.zeros((8,)), xs)
        return out

    with jax.set_mesh(mesh):
        sm = jax.shard_map(f, in_specs=P(), out_specs=P(),
                           check_vma=False)
        c = jaxpr_cost.analyze_fn(sm, jnp.zeros((5, 8)))
    # 5 iterations x 8 floats x 4 bytes x weight 2.0
    assert c.collective_bytes == 5 * 8 * 4 * 2.0


def test_markov_pipeline_deterministic_and_learnable():
    cfg = DataConfig(kind="markov", vocab_size=64, seq_len=32,
                     global_batch=4, seed=7)
    p1, p2 = Pipeline(cfg), Pipeline(cfg)
    b1, b2 = p1.batch(3), p2.batch(3)
    np.testing.assert_array_equal(np.asarray(b1["ids"]),
                                  np.asarray(b2["ids"]))
    # labels are next tokens
    np.testing.assert_array_equal(np.asarray(b1["ids"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))
    # learnable: bigram entropy well below uniform
    big = p1.batch(0)
    H_uniform = np.log(64)
    logp = np.log(p1.table[np.asarray(big["ids"]).reshape(-1),
                           np.asarray(big["labels"]).reshape(-1)])
    assert -logp.mean() < H_uniform - 0.5


def test_fsdp_flatten_meta_roundtrip():
    specs = {"w": ((4, 6), 4), "b": ((6,), 0), "sub": {"u": ((2, 3), 2)}}
    meta = fsdp_lib.flatten_meta(specs)
    n = fsdp_lib.flat_size(meta)
    assert n == 24 + 6 + 6
    flat = jnp.arange(n, dtype=jnp.float32)
    tree = fsdp_lib.unflatten(flat, meta, jnp.float32)
    # order is deterministic (sorted names): b, sub/u, w
    assert tree["b"].shape == (6,)
    assert tree["sub"]["u"].shape == (2, 3)
    assert tree["w"].shape == (4, 6)
    rebuilt = jnp.concatenate(
        [tree["b"].reshape(-1), tree["sub"]["u"].reshape(-1),
         tree["w"].reshape(-1)])
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(flat))


def test_chunk_plan_alignment():
    for n, bucket, M in [(10_000, 256, 8), (1 << 20, 8192, 16),
                         (123, 64, 4), (8192 * 32, 8192, 32)]:
        k, nb_p = fsdp_lib.chunk_plan(n, bucket, M)
        assert nb_p * bucket >= n
        assert nb_p % (M * k) == 0


def test_mesh_helpers():
    from repro.launch.mesh import make_local_mesh, mesh_axes
    mesh = make_local_mesh(tp=1)
    data_axes, model_axis = mesh_axes(mesh)
    assert model_axis == "model"
    assert data_axes == ("data",)

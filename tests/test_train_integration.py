"""End-to-end training behaviour on CPU (single device, mesh 1x1):
loss decreases on a learnable synthetic task, ALQ levels adapt on the
schedule, and 8-bit quantized training tracks fp32 closely."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core.schemes import QuantScheme
from repro.models import Model
from repro.train.data import DataConfig, Pipeline
from repro.train.optim import OptimConfig
from repro.train.train_step import (
    TrainConfig, TrainState, init_train_state, make_train_step)


def run_training(scheme_name, bits, steps=30, sync_mode="all_gather",
                 seed=0, lr=0.3):
    cfg = configs.get_config("paper-proxy")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = Model(cfg, tp=1, dp=1)
    tcfg = TrainConfig(
        scheme=QuantScheme(name=scheme_name, bits=bits, bucket_size=1024),
        optim=OptimConfig(name="adamw", lr=1e-3, weight_decay=0.0),
        sync_mode=sync_mode,
        update_milestones=(2, 10), update_every=0)
    step_fn = make_train_step(model, tcfg, data_axes=("data",))
    pipe = Pipeline(DataConfig(kind="markov", vocab_size=cfg.vocab_size,
                               seq_len=64, global_batch=8, seed=seed))

    pspecs = model.param_specs()
    with jax.set_mesh(mesh):
        state = init_train_state(model, tcfg, jax.random.PRNGKey(seed))
        sspecs = TrainState(
            params=pspecs, opt=type(state.opt)(
                mu=pspecs,
                nu=None if state.opt.nu is None else pspecs, count=P()),
            scheme_state=jax.tree.map(lambda _: P(), state.scheme_state),
            step=P(), rng=P())
        from repro.train.train_step import metric_specs
        train = jax.jit(jax.shard_map(
            step_fn,
            in_specs=(sspecs, {"ids": P("data"), "labels": P("data")}),
            out_specs=(sspecs, metric_specs()),
            check_vma=False))
        losses, levels_hist = [], []
        for t in range(steps):
            state, metrics = train(state, pipe.batch(t))
            losses.append(float(metrics["loss"]))
            levels_hist.append(np.asarray(state.scheme_state.levels))
    return losses, levels_hist, state


def test_loss_decreases_with_alq():
    losses, levels, _ = run_training("alq", bits=3, steps=40)
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_levels_adapt_on_schedule():
    _, levels, state = run_training("alq", bits=3, steps=15)
    # milestones at 2 and 10: levels must have moved after step 2
    assert np.allclose(levels[0], levels[1])
    assert not np.allclose(levels[1], levels[5])
    assert int(state.scheme_state.num_updates) == 2


def test_8bit_quantized_tracks_fp32():
    l_fp, _, _ = run_training("fp32", bits=8, steps=25)
    l_q8, _, _ = run_training("alq", bits=8, steps=25)
    # same data/seed; 8-bit adaptive quantization should track closely
    assert abs(np.mean(l_q8[-5:]) - np.mean(l_fp[-5:])) < 0.15


def test_two_phase_trains():
    losses, _, _ = run_training("alq", bits=4, steps=20,
                                sync_mode="two_phase")
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


@pytest.mark.parametrize("scheme", ["qsgdinf", "nuqsgd", "trn", "amq"])
def test_baselines_train(scheme):
    losses, _, _ = run_training(scheme, bits=3, steps=12)
    assert all(np.isfinite(losses))

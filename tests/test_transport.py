"""dist.transport: the aggregation-rule contracts the simulator and the
fault-tolerant sync path both lean on.

* ``MaskedTransport`` with every worker active reproduces the plain
  ``MeshTransport`` mean: exactly uniform weights, values equal to the
  last ulp (tensordot-of-weights may reassociate the reduction, which
  is why fault-free paths pass ``active=None`` and keep ``mean(0)``);
* transport weights are convex (sum to 1) under any active pattern;
* a single-survivor mask degrades the aggregate to exactly that
  worker's payload;
* ``mean_workers_bucketed`` with an all-valid mask reproduces the
  masked mean, and with a constant-per-worker mask reproduces masking
  that worker out — the bit-exactness seam ``dist.sync`` uses to
  exclude detected-corrupt payloads.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.transport import (
    MaskedTransport,
    MeshTransport,
    Transport,
    make_transport,
)

M, D = 4, 1024
BUCKET = 128
STACKED = jax.random.normal(jax.random.PRNGKey(0), (M, D)) * 0.3


def test_all_active_masked_matches_mesh():
    mesh = Transport(())
    masked = MaskedTransport((), jnp.ones((M,)))
    # exactly uniform weights ...
    np.testing.assert_array_equal(np.asarray(masked.weights()),
                                  np.full(M, 1.0 / M, np.float32))
    # ... and the same mean up to the reduction's last ulp
    ref = np.asarray(mesh.mean_workers(STACKED))
    got = np.asarray(masked.mean_workers(STACKED))
    np.testing.assert_allclose(got, ref, rtol=0,
                               atol=np.spacing(np.abs(ref).max()))


def test_weights_sum_to_one():
    for active in ([1, 1, 1, 1], [1, 0, 1, 0], [1, 0, 0, 0],
                   [1.0, 0.5, 0.0, 0.25]):
        t = MaskedTransport((), jnp.asarray(active, jnp.float32))
        np.testing.assert_allclose(float(jnp.sum(t.weights())), 1.0,
                                   rtol=1e-6)


def test_single_survivor_degrades_to_its_payload():
    t = MaskedTransport((), jnp.asarray([0.0, 0.0, 1.0, 0.0]))
    np.testing.assert_array_equal(np.asarray(t.mean_workers(STACKED)),
                                  np.asarray(STACKED[2]))


def test_bucketed_all_valid_matches_mean_workers():
    t = MaskedTransport((), jnp.asarray([1.0, 1.0, 0.0, 1.0]))
    valid = jnp.ones((M, D // BUCKET), bool)
    np.testing.assert_array_equal(
        np.asarray(t.mean_workers_bucketed(STACKED, valid, BUCKET)),
        np.asarray(t.mean_workers(STACKED)))


def test_bucketed_constant_row_mask_equals_transport_mask():
    # invalidating every bucket of worker 1 must aggregate bit-exactly
    # like masking worker 1 out at the transport (the acceptance seam
    # for integrity-based exclusion in dist.sync)
    valid = jnp.ones((M, D // BUCKET), bool).at[1].set(False)
    got = MeshTransport(()).mean_workers_bucketed(STACKED, valid, BUCKET)
    ref = MaskedTransport(
        (), jnp.asarray([1.0, 0.0, 1.0, 1.0])).mean_workers(STACKED)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_bucketed_all_invalid_bucket_is_zero():
    valid = jnp.ones((M, D // BUCKET), bool).at[:, 3].set(False)
    t = MeshTransport(())
    out = np.asarray(t.mean_workers_bucketed(STACKED, valid, BUCKET))
    np.testing.assert_array_equal(
        out[3 * BUCKET:4 * BUCKET], np.zeros(BUCKET, np.float32))
    # buckets are independent: the others are bit-identical with the
    # all-valid aggregation
    ref = np.asarray(t.mean_workers_bucketed(
        STACKED, jnp.ones((M, D // BUCKET), bool), BUCKET))
    np.testing.assert_array_equal(out[:3 * BUCKET], ref[:3 * BUCKET])


def test_bucketed_nan_in_invalid_bucket_does_not_leak():
    poisoned = STACKED.at[2, 5 * BUCKET].set(jnp.nan)
    valid = jnp.ones((M, D // BUCKET), bool).at[2, 5].set(False)
    out = np.asarray(
        MeshTransport(()).mean_workers_bucketed(poisoned, valid, BUCKET))
    assert np.isfinite(out).all()


def test_make_transport_factory():
    assert isinstance(make_transport(()), MeshTransport)
    t = make_transport((), active=jnp.ones((M,)))
    assert isinstance(t, MaskedTransport)

"""Wire-format coverage (docs/wire_format.md).

* pack/unpack round-trips for every wire width 1..8 at non-word-aligned
  lengths — exercising both the word-boundary spill path (bits not
  dividing 32) and the ``off == 0`` masked-shift path (bits dividing 32);
* packed size is exactly ceil(n*b/32) words;
* ``quantized_allreduce(all_gather)`` on the 8-fake-device mesh equals an
  unpacked (codes-never-packed) reference BIT-exactly — the wire really
  carries packed words, and packing is lossless end to end.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

# word-boundary spill (33: symbol straddles words for b not dividing 32),
# off==0 masked-shift (exact multiples of 32/b), and ragged tails
LENGTHS = [1, 5, 31, 32, 33, 37, 64, 65, 255, 1000]


@pytest.mark.parametrize("bits", range(1, 9))
@pytest.mark.parametrize("n", LENGTHS)
def test_pack_unpack_roundtrip_all_wire_widths(bits, n):
    rng = np.random.default_rng(bits * 10007 + n)
    vals = rng.integers(0, 2 ** bits, size=n, dtype=np.int64)
    packed = packing.pack(jnp.asarray(vals, jnp.int32), bits)
    assert packed.dtype == jnp.uint32
    assert packed.shape[0] == packing.packed_words(n, bits) == -(-n * bits // 32)
    back = packing.unpack(packed, n, bits)
    np.testing.assert_array_equal(np.asarray(back), vals)


@pytest.mark.parametrize("num_levels", [2, 8, 16, 128, 256])
def test_signed_roundtrip_at_scheme_level_counts(num_levels):
    rng = np.random.default_rng(num_levels)
    n = 999  # deliberately non-word-aligned
    codes = rng.integers(-(num_levels - 1), num_levels, size=n)
    packed = packing.pack_signed(jnp.asarray(codes, jnp.int32), num_levels)
    back = packing.unpack_signed(packed, n, num_levels)
    np.testing.assert_array_equal(np.asarray(back), codes)


def test_allreduce_matches_unpacked_reference_bit_exactly():
    body = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import packing
from repro.core.schemes import QuantScheme
from repro.dist import sync
from repro.kernels import ops

scheme = QuantScheme(name="alq", bits=3, bucket_size=256)
state = scheme.init_state()
M = 8
d = 2048  # per-worker length; 8 buckets per worker
mesh = jax.make_mesh((2, 4), ("pod", "data"))
g = jax.random.normal(jax.random.PRNGKey(0), (M, d)) * 0.01
key = jax.random.PRNGKey(7)

def f(gl):
    out, _ = sync.quantized_allreduce(gl.reshape(-1), scheme, state, key,
                                      axes=("pod", "data"))
    return out
smf = jax.jit(jax.shard_map(f, mesh=mesh,
    in_specs=P(("pod", "data")), out_specs=P(), check_vma=False))
packed_out = np.asarray(smf(g))

# unpacked reference: same encode (same folded keys/uniforms), but the
# codes are decoded directly — no pack/all_gather/unpack in the loop
vals = []
for r in range(M):
    vb = g[r].reshape(-1, scheme.bucket_size)
    u = jax.random.uniform(jax.random.fold_in(key, r), vb.shape, jnp.float32)
    codes, norms = ops.quantize_op(vb, u, state.levels,
                                   norm_type=scheme.norm_type)
    # packing must be lossless on the actual code stream too
    w = packing.pack_signed(codes, scheme.num_levels)
    back = packing.unpack_signed(w, codes.size, scheme.num_levels)
    assert (np.asarray(back).reshape(codes.shape)
            == np.asarray(codes, np.int32)).all()
    vals.append(ops.dequantize_op(codes, norms, state.levels).reshape(-1))
ref = np.asarray(jnp.stack(vals).mean(0))
assert (packed_out == ref).all(), np.abs(packed_out - ref).max()
print("WIRE_OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"OUT:{proc.stdout}\nERR:{proc.stderr}"
    assert "WIRE_OK" in proc.stdout
